"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.bucket_pack.ops import (bucket_pack, bucket_unpack,
                                           pad_segments)
from repro.kernels.bucket_pack.ref import bucket_pack_ref, bucket_unpack_ref
from repro.kernels.flash_attention.ops import _ref_fwd, flash_attention
from repro.kernels.rglru_scan.ops import rglru_scan
from repro.kernels.rglru_scan.ref import rglru_scan_ref


class TestBucketPack:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("lengths", [
        (512,), (512, 1024), (2048, 512, 512, 1024), (512,) * 7,
    ])
    def test_pack_roundtrip(self, lengths, dtype):
        key = jax.random.PRNGKey(0)
        vecs = [jax.random.normal(jax.random.fold_in(key, i), (n,)).astype(dtype)
                for i, n in enumerate(lengths)]
        segs, alens = pad_segments(vecs)
        flat = bucket_pack(segs, alens)
        ref = bucket_pack_ref(segs, alens)
        np.testing.assert_array_equal(np.asarray(flat), np.asarray(ref))
        back = bucket_unpack(flat, alens, segs.shape[1])
        ref2 = bucket_unpack_ref(ref, alens, segs.shape[1])
        np.testing.assert_array_equal(np.asarray(back), np.asarray(ref2))

    def test_bad_inputs_raise_value_error(self):
        """User-input validation is real errors, not bare asserts."""
        from repro.kernels.bucket_pack.bucket_pack import (pack_pallas,
                                                           unpack_pallas)
        good = jnp.ones((2, 512))
        with pytest.raises(ValueError, match="multiple of"):
            pack_pallas(jnp.ones((2, 100)), (512, 512))
        with pytest.raises(ValueError, match="aligned lengths"):
            pack_pallas(good, (512,))                 # count mismatch
        with pytest.raises(ValueError, match="positive multiples"):
            pack_pallas(good, (512, 100))             # unaligned length
        with pytest.raises(ValueError, match="must be \\(K, Lmax\\)"):
            pack_pallas(jnp.ones((512,)), (512,))
        with pytest.raises(ValueError, match="multiple of"):
            unpack_pallas(jnp.ones(1024), (512, 512), 100)
        with pytest.raises(ValueError, match="flat buffer shape"):
            unpack_pallas(jnp.ones(512), (512, 512), 512)

    def test_ragged_lengths_align(self):
        key = jax.random.PRNGKey(1)
        vecs = [jax.random.normal(jax.random.fold_in(key, i), (n,))
                for i, n in enumerate([100, 700, 513])]
        segs, alens = pad_segments(vecs)
        assert all(a % 512 == 0 for a in alens)
        flat = bucket_pack(segs, alens)
        # true (unpadded) prefixes survive the roundtrip
        back = bucket_unpack(flat, alens, segs.shape[1])
        off = 0
        for i, v in enumerate(vecs):
            np.testing.assert_allclose(np.asarray(back[i, :v.shape[0]]),
                                       np.asarray(v))
            off += alens[i]


class TestFlashAttention:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize(
        "b,h,hkv,t,hd,causal,window,cap",
        [
            (2, 4, 2, 256, 64, True, 0, 0.0),
            (1, 2, 2, 256, 128, True, 128, 0.0),
            (2, 2, 1, 384, 64, True, 0, 50.0),      # GQA + softcap (gemma2)
            (1, 4, 4, 256, 80, False, 0, 0.0),       # encoder + odd head dim
            (1, 2, 2, 512, 64, True, 100, 30.0),     # window not block-aligned
        ])
    def test_vs_oracle(self, b, h, hkv, t, hd, causal, window, cap, dtype):
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (b, h, t, hd)).astype(dtype)
        k = jax.random.normal(ks[1], (b, hkv, t, hd)).astype(dtype)
        v = jax.random.normal(ks[2], (b, hkv, t, hd)).astype(dtype)
        out = flash_attention(q, k, v, causal, window, cap, 128, 128, True)
        ref = _ref_fwd(q, k, v, causal, window, cap)
        tol = 2e-6 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32), atol=tol)

    @pytest.mark.parametrize("bq,bk", [(64, 64), (64, 128), (128, 256),
                                       (256, 128)])
    def test_block_shape_sweep(self, bq, bk):
        """BlockSpec tiling choices never change the math."""
        ks = jax.random.split(jax.random.PRNGKey(7), 3)
        t = 512
        q = jax.random.normal(ks[0], (1, 2, t, 64))
        k = jax.random.normal(ks[1], (1, 2, t, 64))
        v = jax.random.normal(ks[2], (1, 2, t, 64))
        out = flash_attention(q, k, v, True, 0, 0.0, bq, bk, True)
        ref = _ref_fwd(q, k, v, True, 0, 0.0)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-6)

    def test_gradients_flow(self):
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (1, 2, 128, 64))
        k = jax.random.normal(ks[1], (1, 2, 128, 64))
        v = jax.random.normal(ks[2], (1, 2, 128, 64))

        def f(q, k, v):
            return jnp.sum(flash_attention(q, k, v) ** 2)

        g = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
        for gi in g:
            assert bool(jnp.all(jnp.isfinite(gi)))
            assert float(jnp.max(jnp.abs(gi))) > 0


class TestRGLRUScan:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("b,t,w", [
        (2, 256, 128), (1, 200, 100), (3, 128, 384), (1, 1024, 256),
    ])
    def test_vs_oracle(self, b, t, w, dtype):
        ks = jax.random.split(jax.random.PRNGKey(0), 2)
        a = jax.random.uniform(ks[0], (b, t, w), minval=0.8,
                               maxval=0.999).astype(dtype)
        x = (jax.random.normal(ks[1], (b, t, w)) * 0.1).astype(dtype)
        h = rglru_scan(a, x)
        r = rglru_scan_ref(a, x)
        tol = 2e-6 if dtype == jnp.float32 else 3e-2
        np.testing.assert_allclose(np.asarray(h, np.float32),
                                   np.asarray(r, np.float32), atol=tol)

    def test_gradients_match_reference(self):
        ks = jax.random.split(jax.random.PRNGKey(0), 2)
        a = jax.random.uniform(ks[0], (1, 128, 128), minval=0.8, maxval=0.99)
        x = jax.random.normal(ks[1], (1, 128, 128)) * 0.1
        g1 = jax.grad(lambda a, x: jnp.sum(rglru_scan(a, x) ** 2),
                      argnums=(0, 1))(a, x)
        g2 = jax.grad(lambda a, x: jnp.sum(rglru_scan_ref(a, x) ** 2),
                      argnums=(0, 1))(a, x)
        for u, w_ in zip(g1, g2):
            np.testing.assert_allclose(np.asarray(u), np.asarray(w_),
                                       rtol=1e-4, atol=1e-5)

    def test_rglru_block_uses_kernel(self):
        """models.ssm.apply_rglru(use_kernel=True) matches the XLA path."""
        from repro.configs import get_config
        from repro.models.ssm import apply_rglru, init_rglru_params
        cfg = get_config("recurrentgemma-2b").reduced()
        params = init_rglru_params(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model)) * 0.1
        y1, _ = apply_rglru(params, x, cfg, mode="train", use_kernel=False)
        y2, _ = apply_rglru(params, x, cfg, mode="train", use_kernel=True)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=1e-4, atol=1e-5)
