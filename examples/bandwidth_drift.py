"""Bandwidth-drift demo: watch the schedule re-segment mid-training.

The run-time loop of the paper (Section IV-C), end to end: a ~100M-param
model trains under the DynaComm-bucketed ZeRO trainer while the edge
uplink degrades from 10 Gbps to 1 Gbps at ``--shift-epoch``.  On the epoch
boundary the profiler re-derives pt/gt/Δt from the new network condition,
the DP re-plans, and the dynamic runtime swaps in the compiled step for
the new bucket plan (cached by plan, so a later recovery to 10 Gbps swaps
back without re-tracing).  The whole regime — drifting network included —
is one ``RuntimeConfig`` literal built through ``build_runtime``; the
ASCII timelines show *why* the decision moves: cheaper transmission
favours more, smaller segments overlapped with compute; an expensive link
amortizes Δt over fewer, larger ones.

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python examples/bandwidth_drift.py --steps 60
"""

import argparse
import dataclasses

import jax

from repro.configs import get_config
from repro.core.viz import render_timeline
from repro.runtime import (MeasureConfig, NetworkConfig, RuntimeConfig,
                           ScheduleConfig, build_runtime)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--steps-per-epoch", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--bw-gbps", type=float, default=10.0)
    ap.add_argument("--shift-gbps", type=float, default=1.0)
    ap.add_argument("--shift-epoch", type=int, default=1)
    ap.add_argument("--worker-flops", type=float, default=1e10)
    args = ap.parse_args()

    cfg = dataclasses.replace(
        get_config(args.arch).reduced(num_layers=args.layers,
                                      d_model=args.d_model, vocab=8192),
        name=f"{args.arch}-drift-demo")
    n_dev = len(jax.devices())
    print(f"devices: {n_dev}  arch: {cfg.name}  layers: {cfg.num_layers}  "
          f"uplink: {args.bw_gbps:g} Gbps → {args.shift_gbps:g} Gbps at "
          f"epoch {args.shift_epoch}")

    config = RuntimeConfig(
        runtime="dynamic", arch=cfg.name, batch=args.batch, seq=args.seq,
        schedule=ScheduleConfig(
            reschedule_every=args.steps_per_epoch,
            network=NetworkConfig(bandwidth_gbps=args.bw_gbps,
                                  shift_gbps=args.shift_gbps,
                                  shift_epoch=args.shift_epoch)),
        measure=MeasureConfig(compute_flops_per_s=args.worker_flops))
    rt = build_runtime(config, model=cfg)

    done = 0
    while done < args.steps:
        losses = rt.fit(min(10, args.steps - done))
        done += len(losses)
        print(f"step {done:4d}  epoch {rt.trainer.epoch}  "
              f"loss {losses[-1]:.4f}  buckets "
              f"{len(rt.plan.forward)}/{len(rt.plan.backward)}")

    dyn, net = rt.trainer, rt.trainer.network
    print("\nre-scheduling history:")
    shown = set()
    for e in rt.events:
        ag, rs = dyn.hlo_counts(e.plan)
        print(f"  epoch {e.epoch:3d}: {len(e.plan.forward)} pull / "
              f"{len(e.plan.backward)} push buckets (hlo {ag} ag / {rs} rs)  "
              f"{'RE-SEGMENTED' if e.plan_changed else 'unchanged'}"
              f"{' via step cache' if e.plan_changed and not e.retraced else ''}"
              f"  sched {e.scheduling_seconds * 1e3:.2f} ms "
              f"hidden={e.overhead_hidden}")
        if e.plan not in shown:
            shown.add(e.plan)
            costs = dyn.costs_for_epoch(e.epoch, None, None)
            # forward buckets back to the paper's 1-indexed segments
            segments = tuple((b[0] + 1, b[-1] + 1) for b in e.plan.forward)
            bw = net.model_at(e.epoch).bandwidth_bps / 1e9
            print(f"  --- forward timeline at {bw:g} Gbps ---")
            for line in render_timeline(costs, segments,
                                        phase="forward").splitlines():
                print(f"  {line}")

    changed = any(e.plan_changed for e in rt.events)
    print(f"\nplans traced: {dyn.traces}  cache hits: {dyn.cache_hits}")
    print("schedule re-segmented under drift" if changed
          else "WARNING: decision did not change — try --worker-flops 1e9")


if __name__ == "__main__":
    main()
