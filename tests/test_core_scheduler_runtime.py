"""Run-time scheduler behaviours (Section IV-C) and cost-vector plumbing."""

import numpy as np
import pytest

from repro.configs import INPUT_SHAPES, get_config
from repro.core import (DynaCommScheduler, EdgeNetworkModel, TPUSystemModel,
                        costs_from_profiles, random_costs)
from repro.core.profiler import LayerProfile
from repro.models.profiles import layer_profiles


class TestSchedulerRuntime:
    def test_rescheduling_interval(self):
        c1 = random_costs(10, seed=0, dt=1e-3)
        c2 = random_costs(10, seed=9, dt=1e-3, comm_scale=30.0)
        sched = DynaCommScheduler(strategy="dynacomm", reschedule_every=3)
        d0 = sched.decision_for_iteration(c1)
        d1 = sched.decision_for_iteration(c2)   # iter 1: cached, ignores c2
        assert d0 == d1
        sched.decision_for_iteration(c2)        # iter 2: still cached
        d3 = sched.decision_for_iteration(c2)   # iter 3: re-plans on c2
        assert d3 != d0, "scheduler failed to adapt at the epoch boundary"

    def test_reset(self):
        c = random_costs(6, seed=1, dt=1e-3)
        sched = DynaCommScheduler(reschedule_every=100)
        sched.decision_for_iteration(c)
        sched.reset()
        assert sched._decision is None and sched._iter_seen == 0

    def test_reset_clears_stale_scheduling_time(self):
        c = random_costs(6, seed=1, dt=1e-3)
        sched = DynaCommScheduler(reschedule_every=100)
        sched.decision_for_iteration(c)
        assert sched.last_scheduling_seconds > 0
        sched.reset()
        assert sched.last_scheduling_seconds == 0.0

    @pytest.mark.parametrize("every", [0, -1, -100])
    def test_nonpositive_interval_rejected(self, every):
        """Regression: reschedule_every=0 used to ZeroDivisionError at the
        first decision instead of failing at construction."""
        with pytest.raises(ValueError, match="reschedule_every"):
            DynaCommScheduler(reschedule_every=every)

    def test_unknown_strategy_rejected_at_construction(self):
        with pytest.raises(ValueError, match="unknown strategy"):
            DynaCommScheduler(strategy="nope")

    def test_strategy_plumbs_through(self):
        c = random_costs(8, seed=2, dt=5e-2)
        seq = DynaCommScheduler(strategy="sequential").decision_for_iteration(c)
        lbl = DynaCommScheduler(strategy="lbl").decision_for_iteration(c)
        assert len(seq[0]) == 1 and len(lbl[0]) == 8


class TestCostVectorSources:
    def test_edge_vs_tpu_dt_regimes(self):
        edge = EdgeNetworkModel()
        tpu = TPUSystemModel(data_axis_size=16)
        assert edge.dt > 1e-3           # ~14 ms
        assert tpu.dt < 1e-4            # ~23 µs
        assert edge.dt / tpu.dt > 100

    def test_transfer_scales_with_shards(self):
        small = TPUSystemModel(data_axis_size=2)
        big = TPUSystemModel(data_axis_size=256)
        b = np.array([1e9])
        # (A-1)/A factor: 0.5 vs ~1.0
        assert small.transfer_time(b)[0] < big.transfer_time(b)[0]

    @pytest.mark.parametrize("arch", ["granite-3-2b", "grok-1-314b",
                                      "recurrentgemma-2b"])
    def test_profiles_to_costs_roundtrip(self, arch):
        cfg = get_config(arch)
        profs = layer_profiles(cfg, INPUT_SHAPES["train_4k"])
        costs = costs_from_profiles(profs, net=TPUSystemModel())
        assert costs.num_layers == cfg.num_layers + 2
        assert float(np.sum(costs.fc)) > 0
        assert float(np.sum(costs.pt)) > 0
        # backward defaults to 2x forward
        np.testing.assert_allclose(np.asarray(costs.bc),
                                   2 * np.asarray(costs.fc))

    def test_edge_requires_compute_rate(self):
        profs = [LayerProfile(name="l", param_bytes=1e6, flops_fwd=1e9)]
        with pytest.raises(ValueError):
            costs_from_profiles(profs, net=EdgeNetworkModel())


class TestTimelineViz:
    def test_render_both_phases(self):
        from repro.core.viz import render_timeline
        from repro.core import schedule
        c = random_costs(8, seed=0, dt=1e-3)
        for strat in ("sequential", "lbl", "dynacomm"):
            f, b = schedule(c, strat)
            out_f = render_timeline(c, f, phase="forward")
            out_b = render_timeline(c, b, phase="backward")
            assert "link" in out_f and "compute" in out_f
            assert "makespan" in out_b
            assert len(out_f.splitlines()) == 3
