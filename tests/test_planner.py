"""Tests for ``repro.core.planner`` and the bugfixes that ride with it.

Covers:
* memoized / warm / async planner decisions are *exactly* equal
  (segments and time) to fresh ``schedule`` / ``dp_forward`` /
  ``dp_backward`` solves on randomized costs,
* the DP incumbent/prefix-sum warm-start path of ``dp_forward`` /
  ``dp_backward``,
* the scheduler-restore bugfix (cross-mode / cross-strategy restores
  raise instead of silently rebuilding garbage),
* the ``PlanStepCache`` HLO retention bound + eviction counter,
* the injectable scheduler clock (fixed clock ⇒ bit-identical
  scheduling-seconds streams),
* async-planned vs synchronous-planned training runs are bit-identical.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (AsyncPlanner, LayerCosts, Planner, TopologyCosts,
                        backward_time, consensus_decision, dp_backward,
                        dp_forward, forward_time, schedule,
                        schedule_topology)
from repro.core.scheduler import (STRATEGIES, DynaCommScheduler,
                                  TopologyScheduler)


def _mk(pt, fc, bc, gt, dt, dt_bwd=None):
    return LayerCosts(pt=np.array(pt), fc=np.array(fc), bc=np.array(bc),
                      gt=np.array(gt), dt=dt, dt_bwd=dt_bwd)


def _rand_costs(rng, L=None):
    L = L or rng.integers(2, 9)
    return _mk(rng.uniform(0, 10, L), rng.uniform(0, 10, L),
               rng.uniform(0, 10, L), rng.uniform(0, 10, L),
               float(rng.uniform(0, 5)))


vec = lambda L: st.lists(st.floats(0.0, 100.0), min_size=L, max_size=L)
inst = st.integers(2, 8).flatmap(
    lambda L: st.tuples(vec(L), vec(L), vec(L), vec(L), st.floats(0.0, 10.0)))


# ---------------------------------------------------------------------------
# memoized planning == fresh solves
# ---------------------------------------------------------------------------


class TestPlannerExactness:
    @settings(max_examples=60, deadline=None)
    @given(inst)
    def test_memoized_equals_fresh_schedule(self, tup):
        """decide() == schedule() for every strategy, and the repeat
        lookup is a pure cache hit returning the identical decision."""
        pt, fc, bc, gt, dt = tup
        c = _mk(pt, fc, bc, gt, dt)
        planner = Planner()
        for strat in sorted(STRATEGIES):
            fresh = schedule(c, strat)
            assert planner.decide(c, strat) == fresh
            solves_before = planner.stats.solves + planner.stats.warm_solves
            assert planner.decide(c, strat) == fresh       # hit path
            assert planner.stats.solves + planner.stats.warm_solves == \
                solves_before
        assert planner.stats.hits == len(STRATEGIES)

    @settings(max_examples=60, deadline=None)
    @given(inst, st.floats(0.1, 8.0), st.floats(0.0, 10.0))
    def test_warm_solve_equals_fresh_dp(self, tup, comm_scale, new_dt):
        """Only the communication side moves between two cost points
        (same fc/bc): the second solve warm-starts off the first, and its
        segments + time exactly match a fresh ``dp_forward``/``dp_backward``."""
        pt, fc, bc, gt, dt = tup
        c1 = _mk(pt, fc, bc, gt, dt)
        c2 = _mk([p * comm_scale for p in pt], fc, bc,
                 [g * comm_scale for g in gt], new_dt)
        planner = Planner()
        planner.decide(c1, "dynacomm")                  # cold sibling
        warm_decision = planner.decide(c2, "dynacomm")  # warm path
        assert planner.stats.warm_solves == 1
        f, b = dp_forward(c2), dp_backward(c2)
        assert warm_decision == (f.segments, b.segments)
        # the O(L) evaluation and the DP's prefix-sum arithmetic agree
        # to summation-order noise (the plans themselves are identical)
        assert forward_time(c2, warm_decision[0]) == pytest.approx(
            f.time, rel=1e-12)
        assert backward_time(c2, warm_decision[1]) == pytest.approx(
            b.time, rel=1e-12)

    @settings(max_examples=60, deadline=None)
    @given(inst, inst)
    def test_dp_incumbent_prune_is_exact(self, tup, bound_tup):
        """dp_forward/dp_backward with any *valid* incumbent upper bound
        (the time of a feasible segmentation) return exactly the full
        solve's segments and time."""
        pt, fc, bc, gt, dt = tup
        c = _mk(pt, fc, bc, gt, dt)
        full_f, full_b = dp_forward(c), dp_backward(c)
        # the all-in-one-segment plan is always feasible -> valid bound
        L = c.num_layers
        one_f, one_b = ((1, L),), ((1, L),)
        pruned_f = dp_forward(c, incumbent=forward_time(c, one_f))
        pruned_b = dp_backward(c, incumbent=backward_time(c, one_b))
        assert (pruned_f.segments, pruned_f.time) == \
            (full_f.segments, full_f.time)
        assert (pruned_b.segments, pruned_b.time) == \
            (full_b.segments, full_b.time)
        # prefix-sum reuse is equally exact
        fc_pref = np.concatenate([[0.0], np.cumsum(c.fc)])
        bc_pref = np.concatenate([[0.0], np.cumsum(c.bc[::-1])])
        warm_f = dp_forward(c, incumbent=full_f.time, fc_pref=fc_pref)
        warm_b = dp_backward(c, incumbent=full_b.time, bc_pref=bc_pref)
        assert (warm_f.segments, warm_f.time) == \
            (full_f.segments, full_f.time)
        assert (warm_b.segments, warm_b.time) == \
            (full_b.segments, full_b.time)

    def test_homogeneous_fleet_collapses_to_one_solve(self):
        """W identical workers cost one DP + W-1 dictionary hits."""
        rng = np.random.default_rng(7)
        c = _rand_costs(rng, L=6)
        topo = TopologyCosts(workers=tuple(c for _ in range(16)))
        planner = Planner()
        decisions = planner.decide_topology(topo, "dynacomm")
        assert decisions == schedule_topology(topo, "dynacomm")
        assert planner.stats.solves == 1
        assert planner.stats.hits == 15

    def test_consensus_matches_uncached_and_caches_topology(self):
        rng = np.random.default_rng(11)
        workers = tuple(_rand_costs(rng, L=5) for _ in range(4))
        topo = TopologyCosts(workers=workers)
        planner = Planner()
        got = planner.consensus(topo, "dynacomm")
        want = consensus_decision(topo, "dynacomm")
        assert got == want
        # revisit: whole-topology dictionary hit, no new solves
        solves = planner.stats.solves + planner.stats.warm_solves
        hits = planner.stats.hits
        assert planner.consensus(topo, "dynacomm") == want
        assert planner.stats.solves + planner.stats.warm_solves == solves
        assert planner.stats.hits == hits + 1

    def test_lru_eviction_counter_and_bound(self):
        rng = np.random.default_rng(3)
        planner = Planner(cache_size=2)
        for _ in range(5):
            planner.decide(_rand_costs(rng, L=4), "sequential")
        assert len(planner) <= 2
        assert planner.stats.evictions == 3

    def test_validation(self):
        with pytest.raises(ValueError, match="cache_size"):
            Planner(cache_size=0)
        with pytest.raises(ValueError, match="strategy"):
            Planner().decide(_rand_costs(np.random.default_rng(0)), "magic")

    def test_clear_drops_entries_but_keeps_counters(self):
        rng = np.random.default_rng(5)
        planner = Planner()
        c = _rand_costs(rng, L=4)
        planner.decide(c, "dynacomm")
        planner.clear()
        assert len(planner) == 0
        assert planner.stats.solves == 1
        planner.decide(c, "dynacomm")      # re-solve, not a hit
        assert planner.stats.solves == 2


# ---------------------------------------------------------------------------
# async two-phase protocol
# ---------------------------------------------------------------------------


class TestAsyncPlanner:
    def test_submit_collect_is_bit_identical_to_sync(self):
        rng = np.random.default_rng(21)
        costs = [_rand_costs(rng, L=6) for _ in range(8)]
        sync = Planner()
        want = [sync.decide(c, "dynacomm") for c in costs]
        ap = AsyncPlanner()
        try:
            for c in costs:
                assert ap.submit(c, "dynacomm") is True
            ap.drain()
            got = [ap.decide(c, "dynacomm") for c in costs]
        finally:
            ap.close()
        assert got == want
        assert ap.stats.async_submitted == len(costs)
        assert ap.stats.sync_fallbacks == 0
        # drained jobs land in the decision cache: collects are hits
        assert ap.stats.hits == len(costs)

    def test_duplicate_submit_is_refused(self):
        rng = np.random.default_rng(23)
        c = _rand_costs(rng, L=5)
        ap = AsyncPlanner()
        try:
            assert ap.submit(c, "dynacomm") is True
            assert ap.submit(c, "dynacomm") is False   # in flight or cached
            ap.drain()
            assert ap.submit(c, "dynacomm") is False   # cached
        finally:
            ap.close()
        assert ap.stats.async_submitted == 1

    def test_sync_fallback_without_submit(self):
        rng = np.random.default_rng(29)
        c = _rand_costs(rng, L=5)
        ap = AsyncPlanner()
        try:
            got = ap.decide(c, "dynacomm")
        finally:
            ap.close()
        assert got == schedule(c, "dynacomm")
        assert ap.stats.sync_fallbacks == 1
        assert ap.stats.async_submitted == 0

    def test_submit_topology_counts_new_jobs(self):
        rng = np.random.default_rng(31)
        c = _rand_costs(rng, L=5)
        topo = TopologyCosts(workers=(c, c, c, _rand_costs(rng, L=5)))
        ap = AsyncPlanner()
        try:
            # three identical workers -> one job; fourth distinct -> one
            assert ap.submit_topology(topo, "dynacomm") == 2
            ap.drain()
        finally:
            ap.close()

    def test_close_is_idempotent(self):
        ap = AsyncPlanner()
        ap.close()
        ap.close()


# ---------------------------------------------------------------------------
# scheduler-restore bugfix
# ---------------------------------------------------------------------------


class TestSchedulerRestore:
    def test_topology_cross_mode_restore_raises(self):
        a = TopologyScheduler(strategy="dynacomm", mode="per-worker")
        b = TopologyScheduler(strategy="dynacomm", mode="consensus")
        with pytest.raises(ValueError, match="mode"):
            b.load_state_dict(a.state_dict())

    def test_topology_cross_strategy_restore_raises(self):
        a = TopologyScheduler(strategy="lbl")
        b = TopologyScheduler(strategy="dynacomm")
        with pytest.raises(ValueError, match="strategy"):
            b.load_state_dict(a.state_dict())

    def test_dynacomm_cross_strategy_restore_raises(self):
        a = DynaCommScheduler(strategy="ibatch")
        b = DynaCommScheduler(strategy="dynacomm")
        with pytest.raises(ValueError, match="strategy"):
            b.load_state_dict(a.state_dict())

    def test_same_mode_roundtrip_restores_decision(self):
        rng = np.random.default_rng(13)
        topo = TopologyCosts(workers=tuple(_rand_costs(rng, L=4)
                                           for _ in range(3)))
        a = TopologyScheduler(strategy="dynacomm", mode="per-worker",
                              reschedule_every=4)
        a.decision_for_iteration(topo)
        b = TopologyScheduler(strategy="dynacomm", mode="per-worker",
                              reschedule_every=4)
        b.load_state_dict(a.state_dict())
        assert b.state_dict() == a.state_dict()

    def test_legacy_state_without_mode_loads(self):
        """Pre-fix checkpoints carry no mode/strategy keys — they load
        into a matching scheduler (nothing to validate against)."""
        a = TopologyScheduler(strategy="dynacomm", mode="consensus")
        state = a.state_dict()
        del state["mode"], state["strategy"]
        b = TopologyScheduler(strategy="dynacomm", mode="consensus")
        b.load_state_dict(state)            # no raise
        assert b._iter_seen == 0


# ---------------------------------------------------------------------------
# PlanStepCache HLO retention bugfix
# ---------------------------------------------------------------------------


class TestHloRetention:
    def _cache_with_compiles(self, retention, num_plans):
        import jax.numpy as jnp
        from repro.core.buckets import plan_from_decision
        from repro.runtime.replan import PlanStepCache
        cache = PlanStepCache(hlo_retention=retention)
        state, batch = jnp.zeros((4,)), jnp.ones((4,))
        plans = []
        for n in range(1, num_plans + 1):
            # merge the first n layers into one bucket -> distinct plans
            fwd = ((1, n),) + tuple((i, i) for i in range(n + 1, 5))
            plan = plan_from_decision(fwd, ((1, 4),), 4)
            plans.append(plan)
            cache.step_for(plan, lambda: (lambda s, b: s + b),
                           state, batch, count_hit=True)
        return cache, plans

    def test_retention_bound_and_eviction_counter(self):
        cache, plans = self._cache_with_compiles(retention=2, num_plans=4)
        assert cache.hlo_evictions == 2
        assert len(cache._hlo_text) == 2
        # newest two retained, oldest two evicted
        cache.hlo_text(plans[-1])
        cache.hlo_text(plans[-2])
        with pytest.raises(KeyError, match="evicted"):
            cache.hlo_text(plans[0])
        # compiled steps and collective counts are NOT evicted
        assert len(cache.plans) == 4
        assert cache.hlo_counts(plans[0]) is not None

    def test_retention_validation(self):
        from repro.runtime.replan import PlanStepCache
        with pytest.raises(ValueError, match="hlo_retention"):
            PlanStepCache(hlo_retention=0)


# ---------------------------------------------------------------------------
# injectable clock (DET-WALL-CLOCK bugfix)
# ---------------------------------------------------------------------------


class TestInjectableClock:
    def _ticker(self):
        t = [0.0]

        def clock():
            t[0] += 0.5
            return t[0]
        return clock

    def test_fixed_clock_streams_are_bit_identical(self):
        rng = np.random.default_rng(17)
        knots = [_rand_costs(rng, L=5) for _ in range(4)]

        def run():
            sched = DynaCommScheduler(strategy="dynacomm",
                                      reschedule_every=1,
                                      clock=self._ticker())
            out = []
            for c in knots:
                sched.decision_for_iteration(c)
                out.append(sched.last_scheduling_seconds)
            return out
        a, b = run(), run()
        assert a == b == [0.5] * 4        # exactly one tick per re-plan

    def test_topology_scheduler_accepts_clock(self):
        rng = np.random.default_rng(19)
        topo = TopologyCosts(workers=tuple(_rand_costs(rng, L=4)
                                           for _ in range(2)))
        sched = TopologyScheduler(strategy="dynacomm", reschedule_every=1,
                                  clock=self._ticker())
        sched.decision_for_iteration(topo)
        assert sched.last_scheduling_seconds == 0.5


# ---------------------------------------------------------------------------
# async-planned runs are bit-identical to synchronous-planned runs
# ---------------------------------------------------------------------------


class TestAsyncPlanningBitIdentity:
    def test_fleet_async_equals_sync(self):
        """Same losses, same plans, same replan events — only the
        planner's thread placement differs; plus the homogeneous-fleet
        cache collapse shows up as a nonzero hit rate."""
        import jax.numpy as jnp

        from repro.fleet import FleetSchedule, FleetTrainer
        from repro.optim import sgd

        rng = np.random.default_rng(0)
        layers = [{"w": jnp.asarray(rng.standard_normal(8), jnp.float32)}
                  for _ in range(3)]

        def loss_fn(layer_list, batch):
            return sum(jnp.sum((l["w"] - batch["t"]) ** 2)
                       for l in layer_list) / len(layer_list)

        def batch_fn(w, i):
            del w, i
            return {"t": jnp.zeros((8,), jnp.float32)}

        schedule = FleetSchedule.synthesize(range(8), churn=2.0,
                                            horizon=2.0, seed=5)

        def run(async_planning):
            tr = FleetTrainer(init_layers=layers, loss_fn=loss_fn,
                              optimizer=sgd(1e-2, 0.0), workers=8,
                              schedule=schedule, throttle="wait",
                              async_planning=async_planning)
            log = tr.run(48, batch_fn)
            key = [(e.worker, e.sim_time, e.version, e.loss)
                   for e in log.events]
            replans = [(e.reason, e.num_workers, e.plan_changed)
                       for e in tr.replan_events]
            return key, replans, tr.planner_stats

        sync_key, sync_replans, _ = run(False)
        async_key, async_replans, stats = run(True)
        assert async_key == sync_key
        assert async_replans == sync_replans
        assert stats["hit_rate"] > 0       # homogeneous collapse
