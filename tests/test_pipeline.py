"""repro.pipeline: partitioner, schedules, transfers, trainer, wiring.

Property layer (hypothesis): the stage partitioner's DP equals brute
force and respects the balanced-load bound; 1F1B streams satisfy their
ordering/in-flight invariants; the analytic bubble fraction equals the
event-driven simulation; DynaComm-segmented boundary transfers never
lose to the whole-tensor baseline.  Integration layer: the trainer's
losses are bit-identical across stage counts (the S=1 run is the
single-device execution of the same decomposition) and match the fused
single-device step to fp32 roundoff; checkpoint resume is bitwise; the
planner decision cache persists through save/restore (resumed re-plans
are pure cache hits).  The 4-forged-device variant (per-stage HLO
collective audit, device placement) lives in
``tests/helpers/pipeline_check.py`` behind ``-m slow``.
"""

import itertools
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.core import EdgeNetworkModel, Planner, dp_partition
from repro.optim import adamw
from repro.pipeline import (EMBED_LINK, PipelineTrainer,
                            analytic_bubble_fraction, boundary_costs,
                            gpipe_schedule, make_schedule,
                            one_f_one_b_schedule, partition_loads,
                            partition_profiles, plan_boundary, simulate,
                            whole_tensor_decision)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

loads_strategy = st.integers(1, 8).flatmap(
    lambda L: st.tuples(
        st.lists(st.floats(0.01, 100.0), min_size=L, max_size=L),
        st.integers(1, L)))


def _brute_force_bottleneck(loads, parts):
    """Min over all contiguous splits of the max part sum."""
    L = len(loads)
    best = float("inf")
    for cuts in itertools.combinations(range(1, L), parts - 1):
        edges = (0,) + cuts + (L,)
        bottleneck = max(sum(loads[a:b])
                         for a, b in zip(edges[:-1], edges[1:]))
        best = min(best, bottleneck)
    return best


class TestPartition:
    @settings(max_examples=100, deadline=None)
    @given(loads_strategy)
    def test_dp_matches_brute_force(self, inst):
        loads, parts = inst
        result = dp_partition(loads, parts)
        assert result.bottleneck == pytest.approx(
            _brute_force_bottleneck(loads, parts), rel=1e-9)

    @settings(max_examples=100, deadline=None)
    @given(loads_strategy)
    def test_balanced_load_bound(self, inst):
        """bottleneck <= total/parts + max single load (greedy bound)."""
        loads, parts = inst
        result = dp_partition(loads, parts)
        assert result.bottleneck <= \
            sum(loads) / parts + max(loads) + 1e-9

    @settings(max_examples=100, deadline=None)
    @given(loads_strategy)
    def test_segments_cover_contiguously(self, inst):
        loads, parts = inst
        part = partition_loads(loads, parts)
        assert part.segments[0][0] == 1
        assert part.segments[-1][1] == len(loads)
        for (_, hi), (lo, _) in zip(part.segments, part.segments[1:]):
            assert lo == hi + 1
        for s, (lo, hi) in enumerate(part.segments):
            for l in range(lo - 1, hi):
                assert part.stage_of[l] == s
            assert part.layers_of(s) == tuple(range(lo - 1, hi))

    def test_profiles_partition_rejects_too_many_stages(self):
        from repro.configs.base import InputShape
        from repro.models.profiles import layer_profiles
        cfg = get_config("granite-3-2b").reduced()
        profiles = layer_profiles(cfg, InputShape("t", 16, 2, "train"))
        with pytest.raises(ValueError, match="stages"):
            partition_profiles(profiles, len(profiles) + 1,
                               compute_flops_per_s=1e12)


sm_strategy = st.tuples(st.integers(1, 4), st.integers(1, 8))


class TestSchedule:
    @settings(max_examples=60, deadline=None)
    @given(sm_strategy)
    def test_one_f_one_b_in_flight_bound(self, sm):
        """Stage s keeps at most min(S - s, M) forwards in flight."""
        S, M = sm
        sched = one_f_one_b_schedule(S, M)
        for s, stream in enumerate(sched.streams):
            in_flight = peak = 0
            for task in stream:
                in_flight += 1 if task.kind == "F" else -1
                peak = max(peak, in_flight)
            assert in_flight == 0
            assert peak <= min(S - s, M)

    @settings(max_examples=60, deadline=None)
    @given(sm_strategy)
    def test_one_f_one_b_backward_follows_forward(self, sm):
        """B(m) never precedes F(m) in any stage stream."""
        S, M = sm
        sched = one_f_one_b_schedule(S, M)
        for stream in sched.streams:
            seen_fwd = set()
            for task in stream:
                if task.kind == "F":
                    seen_fwd.add(task.microbatch)
                else:
                    assert task.microbatch in seen_fwd

    @settings(max_examples=60, deadline=None)
    @given(sm_strategy)
    def test_gpipe_fill_then_drain(self, sm):
        S, M = sm
        sched = gpipe_schedule(S, M)
        for stream in sched.streams:
            kinds = [t.kind for t in stream]
            assert kinds == ["F"] * M + ["B"] * M

    @pytest.mark.parametrize("name", ("gpipe", "1f1b"))
    @pytest.mark.parametrize("S,M", [(1, 1), (2, 4), (3, 2), (4, 8)])
    def test_analytic_bubble_equals_simulated(self, name, S, M):
        sched = make_schedule(name, S, M)
        tl = simulate(sched, [1.0] * S, [2.0] * S)
        assert tl.bubble_fraction == pytest.approx(
            analytic_bubble_fraction(S, M), abs=1e-12)

    def test_simulate_charges_boundary_transfers(self):
        sched = make_schedule("1f1b", 2, 2)
        free = simulate(sched, [1.0, 1.0], [1.0, 1.0])
        slow = simulate(sched, [1.0, 1.0], [1.0, 1.0],
                        fwd_transfer=[0.5], bwd_transfer=[0.5])
        assert slow.makespan > free.makespan


class TestTransfer:
    NET = EdgeNetworkModel(bandwidth_bps=0.1e9)

    transfer_strategy = st.tuples(
        st.floats(1e4, 1e8),          # activation bytes
        st.integers(1, 6),            # microbatches
        st.integers(1, 4),            # chunks
        st.floats(1e-4, 0.5),         # stage fwd seconds
        st.floats(1e-4, 0.5))         # stage bwd seconds

    @settings(max_examples=60, deadline=None)
    @given(transfer_strategy)
    def test_segmented_never_loses_to_whole(self, inst):
        act, M, chunks, f, b = inst
        costs = boundary_costs(act, M, net=self.NET, stage_fwd_s=f,
                               stage_bwd_s=b, chunks=chunks)
        plan = plan_boundary(0, costs, microbatches=M, chunks=chunks)
        assert plan.fwd_time <= plan.whole_fwd_time + 1e-9
        assert plan.bwd_time <= plan.whole_bwd_time + 1e-9
        assert plan.speedup >= 1.0 - 1e-9

    def test_boundary_costs_structure(self):
        costs = boundary_costs(1e6, 3, net=self.NET, stage_fwd_s=0.05,
                               stage_bwd_s=0.1, chunks=2)
        assert costs.num_layers == 6
        np.testing.assert_allclose(costs.fc, [0, .05, 0, .05, 0, .05])
        np.testing.assert_allclose(costs.bc, [.1, 0, .1, 0, .1, 0])
        f, b = whole_tensor_decision(costs)
        assert f == ((1, 6),) and b == ((1, 6),)

    def test_segmentation_wins_at_edge_bandwidth(self):
        """The tentpole scenario: 100 Mbps, strict win over whole-tensor."""
        costs = boundary_costs(32 * 128 * 512 * 4, 4, net=self.NET,
                               stage_fwd_s=0.05, stage_bwd_s=0.1, chunks=4)
        plan = plan_boundary(0, costs, microbatches=4, chunks=4)
        assert plan.speedup > 1.05

    def test_homogeneous_boundaries_hit_planner_cache(self):
        planner = Planner(cache_size=8)
        costs = boundary_costs(1e6, 4, net=self.NET, stage_fwd_s=0.05,
                               stage_bwd_s=0.1, chunks=2)
        p0 = plan_boundary(0, costs, planner=planner, microbatches=4,
                           chunks=2)
        p1 = plan_boundary(1, costs, planner=planner, microbatches=4,
                           chunks=2)
        assert p0.decision == p1.decision
        assert planner.stats.solves == 1 and planner.stats.hits == 1


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("granite-3-2b").reduced()
    toks = jax.random.randint(jax.random.PRNGKey(3), (4, 16), 0,
                              cfg.vocab_size)
    return cfg, {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}


def _run_trainer(cfg, batch, S, M, steps=2, **kw):
    tr = PipelineTrainer(cfg=cfg, optimizer=adamw(1e-3), num_stages=S,
                         num_microbatches=M, **kw)
    state = tr.init_state(jax.random.PRNGKey(0))
    losses = []
    for _ in range(steps):
        state, loss = tr.step(state, batch)
        losses.append(float(loss))
    return tr, state, losses


class TestTrainer:
    @pytest.mark.parametrize("M", (1, 2))
    def test_bit_identical_across_stage_counts(self, tiny, M):
        cfg, batch = tiny
        ref = _run_trainer(cfg, batch, 1, M)[2]
        for S in (2, 4):
            assert _run_trainer(cfg, batch, S, M)[2] == ref

    def test_matches_single_device_reference(self, tiny):
        from repro.models import init_params, train_loss
        cfg, batch = tiny
        params = init_params(cfg, jax.random.PRNGKey(0))
        opt = adamw(1e-3)
        ostate = opt.init(params)

        @jax.jit
        def ref_step(params, ostate):
            loss, grads = jax.value_and_grad(
                lambda p: train_loss(cfg, p, batch, aux_weight=0.01))(params)
            params, ostate = opt.update(grads, ostate, params)
            return params, ostate, loss

        ref = []
        for _ in range(2):
            params, ostate, loss = ref_step(params, ostate)
            ref.append(float(loss))
        np.testing.assert_allclose(
            _run_trainer(cfg, batch, 2, 2)[2], ref, rtol=2e-5)

    def test_gpipe_matches_one_f_one_b(self, tiny):
        """Execution order differs; the summed numerators must not."""
        cfg, batch = tiny
        a = _run_trainer(cfg, batch, 2, 2, schedule_name="gpipe")[2]
        b = _run_trainer(cfg, batch, 2, 2, schedule_name="1f1b")[2]
        assert a == b

    def test_ledger_counts_exact(self, tiny):
        cfg, batch = tiny
        tr, _, _ = _run_trainer(cfg, batch, 2, 2, steps=2)
        led = tr.ledger
        # 2 steps x (2 microbatch acts across 1 boundary + 1 embed pull)
        assert led["num_pulls"] == 2 * (2 * 1 + 1)
        # 2 steps x (2 grads across 1 boundary + 2 embed-grad returns)
        assert led["num_pushes"] == 2 * (2 * 1 + 2)
        assert EMBED_LINK in led["boundary_pull_bytes"]

    def test_microbatch_divisibility_enforced(self, tiny):
        cfg, batch = tiny
        tr = PipelineTrainer(cfg=cfg, optimizer=adamw(1e-3), num_stages=2,
                             num_microbatches=3)
        state = tr.init_state(jax.random.PRNGKey(0))
        with pytest.raises(ValueError, match="divisible"):
            tr.step(state, batch)

    def test_save_restore_resume_bitwise(self, tmp_path):
        from repro.runtime import RuntimeConfig, build_runtime
        cfg = RuntimeConfig.load(os.path.join(
            REPO, "examples", "runtime_configs", "pipeline.json"))
        rt = build_runtime(cfg)
        rt.fit(2)
        path = str(tmp_path / "pipe.npz")
        rt.save_state(path)
        cont = rt.fit(2)
        rt2 = build_runtime(cfg)
        rt2.restore_state(path)
        assert rt2.fit(2) == cont

    def test_transfer_plans_ride_cost_model(self, tiny):
        cfg, batch = tiny
        net = EdgeNetworkModel(bandwidth_bps=0.1e9)
        from repro.core import costs_from_profiles
        from repro.configs.base import InputShape
        from repro.models.profiles import layer_profiles
        profiles = layer_profiles(cfg, InputShape("t", 16, 4, "train"))
        costs = costs_from_profiles(profiles, net=net,
                                    compute_flops_per_s=1e10)
        tr, _, _ = _run_trainer(cfg, batch, 2, 2, costs=costs, net=net,
                                transfer_chunks=2)
        plans = tr.transfer_plans()
        assert len(plans) == 1
        assert plans[0].speedup >= 1.0
        tl = tr.timeline()
        assert tl is not None and tl.makespan > 0


class TestRuntimeWiring:
    def test_pipeline_config_validation(self):
        from repro.runtime import PipelineConfig, RuntimeConfig
        with pytest.raises(ValueError, match="schedule"):
            PipelineConfig(schedule="interleaved")
        with pytest.raises(ValueError, match="pipeline"):
            RuntimeConfig(runtime="zero", batch=2, seq=16,
                          pipeline=PipelineConfig())
        with pytest.raises(ValueError, match="divisible|microbatches"):
            RuntimeConfig(runtime="pipeline", batch=3, seq=16,
                          pipeline=PipelineConfig(microbatches=2))
        cfg = RuntimeConfig(runtime="pipeline", batch=4, seq=16)
        assert cfg.pipeline is not None      # auto-materialized block

    def test_smoke_config_builds_and_steps(self):
        from repro.runtime import RuntimeConfig, build_runtime
        cfg = RuntimeConfig.load(os.path.join(
            REPO, "examples", "runtime_configs", "pipeline.json"))
        rt = build_runtime(cfg)
        losses = rt.fit(1)
        assert len(losses) == 1 and np.isfinite(losses[0])
        assert rt.partition.num_stages == cfg.pipeline.stages
        assert rt.ledger["num_pulls"] > 0

    def test_launcher_flags_require_pipeline_runtime(self):
        env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
        proc = subprocess.run(
            [sys.executable, "-m", "repro.launch.train", "--runtime",
             "local", "--stages", "2", "--steps", "1"],
            capture_output=True, text=True, env=env, timeout=120)
        assert proc.returncode != 0
        assert "--runtime pipeline" in proc.stderr


class TestPlannerPersistence:
    def test_state_dict_round_trips_through_json(self):
        from repro.core import random_costs
        planner = Planner(cache_size=8)
        costs = [random_costs(5, seed=s, dt=1e-3) for s in range(3)]
        decisions = [planner.decide(c, "dynacomm") for c in costs]
        blob = json.dumps(planner.state_dict())
        restored = Planner(cache_size=8)
        restored.load_state_dict(json.loads(blob))
        assert [restored.decide(c, "dynacomm") for c in costs] == decisions
        assert restored.stats.hits == 3 and restored.stats.solves == 0

    def test_resumed_replan_is_cache_hit(self, tmp_path):
        """Dynamic runtime: save mid-run, restore fresh, re-plan at the
        next epoch boundary — the restored decision cache must serve it
        without a single new DP solve."""
        from repro.runtime import (NetworkConfig, RuntimeConfig,
                                   ScheduleConfig, build_runtime)
        cfg = RuntimeConfig(
            runtime="dynamic", batch=2, seq=16,
            schedule=ScheduleConfig(
                strategy="dynacomm", reschedule_every=2,
                network=NetworkConfig(bandwidth_gbps=1.0, shift_gbps=0.1,
                                      shift_epoch=1)))
        rt = build_runtime(cfg)
        rt.fit(3)                       # crosses a re-plan boundary
        assert len(rt.trainer.planner) > 0
        path = str(tmp_path / "ck.npz")
        rt.save_state(path)
        rt2 = build_runtime(cfg)
        rt2.restore_state(path)
        assert len(rt2.trainer.planner) == len(rt.trainer.planner)
        rt2.fit(3)                      # next boundary re-plans
        stats = rt2.trainer.planner.stats
        assert stats.hits > 0, stats.as_dict()
        assert stats.solves == 0, stats.as_dict()


@pytest.mark.slow
class TestPipelineMultiDevice:
    @pytest.fixture(scope="class")
    def result(self):
        env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
        env.pop("XLA_FLAGS", None)
        proc = subprocess.run(
            [sys.executable, os.path.join(REPO, "tests", "helpers",
                                          "pipeline_check.py")],
            capture_output=True, text=True, env=env, timeout=1200)
        assert proc.returncode == 0, proc.stderr[-3000:]
        return json.loads(proc.stdout.strip().splitlines()[-1])

    def test_losses_bit_identical_across_stage_counts(self, result):
        for M in (1, 4):
            ref = result["losses"][f"S1M{M}"]
            for S in (2, 4):
                assert result["losses"][f"S{S}M{M}"] == ref, (S, M)

    def test_matches_single_device_reference(self, result):
        np.testing.assert_allclose(result["losses"]["S4M4"],
                                   result["reference_losses"], rtol=2e-5)

    def test_stage_programs_have_zero_collectives(self, result):
        for s, counts in enumerate(result["stage_collectives"]):
            assert counts == {"fwd": 0, "bwd": 0}, (s, counts)

    def test_ledger_counts_exact(self, result):
        led = result["ledger"]
        assert led["num_pulls"] == led["expected_pulls"]
        assert led["num_pushes"] == led["expected_pushes"]
        assert led["pull_bytes"] == led["expected_pull_bytes"]
        assert led["push_bytes"] == led["expected_push_bytes"]
