"""The repro.runtime layer: config round-trips, the registry, the Trainer
protocol over every regime, deprecation shims, BSP push aggregation, and
measured per-worker PS costs (ISSUE 5).

Every registered runtime is built from its checked-in smoke config
(``examples/runtime_configs/*.json``) and driven single-device; invalid
config combinations must fail at construction with clear ValueErrors.
"""

import glob
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime import (ExecutionConfig, FleetConfig, FleetEventConfig,
                           MeasureConfig, NetworkConfig, RuntimeConfig,
                           ScheduleConfig, TopologyConfig, Trainer,
                           build_runtime, runtime_names)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SMOKE_DIR = os.path.join(REPO, "examples", "runtime_configs")

SMOKE = dict(batch=2, seq=16, reduced=True)


def smoke_config_paths():
    paths = sorted(glob.glob(os.path.join(SMOKE_DIR, "*.json")))
    assert paths, f"no smoke configs under {SMOKE_DIR}"
    return paths


# ---------------------------------------------------------------------------
# config: JSON round-trip + validation
# ---------------------------------------------------------------------------


class TestRuntimeConfig:
    def test_every_smoke_config_round_trips(self):
        names = set()
        for path in smoke_config_paths():
            c = RuntimeConfig.load(path)
            assert RuntimeConfig.from_json(c.to_json()) == c, path
            names.add(c.runtime)
        # one smoke config per registered runtime
        assert names == set(runtime_names())

    def test_nested_heterogeneous_round_trip(self):
        c = RuntimeConfig(
            runtime="ps-async", **SMOKE,
            execution=ExecutionConfig(staleness=0, throttle="wait",
                                      aggregate=True),
            schedule=ScheduleConfig(topology=TopologyConfig(
                servers=3, workers=4, down_gbps=(10.0, 10.0, 2.5, 2.5),
                up_gbps=(1.0, 1.0, 0.25, 0.25),
                worker_flops=(4e10, 4e10, 1e10, 1e10))),
            measure=MeasureConfig(remeasure_every=3))
        again = RuntimeConfig.from_json(c.to_json())
        assert again == c
        assert again.schedule.topology.down_gbps == (10.0, 10.0, 2.5, 2.5)

    def test_from_dict_and_json_string_inputs(self):
        c = RuntimeConfig(runtime="zero", **SMOKE)
        assert build_runtime is not None
        assert RuntimeConfig.from_dict(c.to_dict()) == c

    def test_unknown_runtime_rejected(self):
        with pytest.raises(ValueError, match="unknown runtime"):
            RuntimeConfig(runtime="psychic")

    def test_unknown_top_level_field_rejected(self):
        with pytest.raises(ValueError, match="unknown RuntimeConfig"):
            RuntimeConfig.from_dict({"runtime": "zero", "warp": 9})

    def test_staleness_on_sync_runtime_rejected(self):
        with pytest.raises(ValueError, match="staleness"):
            RuntimeConfig(runtime="zero",
                          execution=ExecutionConfig(staleness=1))
        with pytest.raises(ValueError, match="staleness"):
            RuntimeConfig(runtime="ps",
                          execution=ExecutionConfig(staleness=1))

    def test_aggregate_needs_wait_throttle(self):
        with pytest.raises(ValueError, match="wait"):
            ExecutionConfig(throttle="reject", aggregate=True)

    def test_aggregate_rejects_inert_staleness(self):
        """Cohort admission makes k inert under aggregation — a non-zero
        bound is a configuration the runtime would silently ignore."""
        with pytest.raises(ValueError, match="inert"):
            ExecutionConfig(throttle="wait", aggregate=True, staleness=2)

    def test_aggregate_on_sync_runtime_rejected(self):
        with pytest.raises(ValueError, match="aggregate"):
            RuntimeConfig(runtime="dynamic-ps",
                          execution=ExecutionConfig(throttle="wait",
                                                    aggregate=True))

    def test_topology_on_zero_regime_rejected(self):
        with pytest.raises(ValueError, match="topology"):
            RuntimeConfig(runtime="dynamic",
                          schedule=ScheduleConfig(topology=TopologyConfig()))

    def test_network_on_ps_regime_rejected(self):
        with pytest.raises(ValueError, match="network"):
            RuntimeConfig(runtime="ps",
                          schedule=ScheduleConfig(network=NetworkConfig()))

    def test_drift_on_static_runtime_needs_dynamic(self):
        with pytest.raises(ValueError, match="dynamic"):
            RuntimeConfig(runtime="zero",
                          schedule=ScheduleConfig(
                              network=NetworkConfig(shift_gbps=1.0)))
        with pytest.raises(ValueError, match="dynamic-ps"):
            RuntimeConfig(runtime="ps",
                          schedule=ScheduleConfig(
                              topology=TopologyConfig(up_shift_factor=4.0)))

    def test_drift_detect_only_on_dynamic(self):
        with pytest.raises(ValueError, match="drift_detect"):
            RuntimeConfig(runtime="zero",
                          schedule=ScheduleConfig(drift_detect=True))

    def test_measured_only_on_dynamic_sync(self):
        with pytest.raises(ValueError, match="measured"):
            RuntimeConfig(runtime="zero",
                          measure=MeasureConfig(cost_source="measured"))

    def test_regime_mismatch_rejected(self):
        with pytest.raises(ValueError, match="contradicts"):
            RuntimeConfig(runtime="zero",
                          execution=ExecutionConfig(regime="ps-sync"))

    def test_network_and_topology_mutually_exclusive(self):
        with pytest.raises(ValueError, match="not both"):
            ScheduleConfig(network=NetworkConfig(),
                           topology=TopologyConfig())

    def test_regime_and_is_dynamic_views(self):
        c = RuntimeConfig(runtime="dynamic-ps-async",
                          execution=ExecutionConfig(staleness=1))
        assert c.regime == "ps-async" and c.is_dynamic
        assert not RuntimeConfig(runtime="ps").is_dynamic

    def test_per_worker_length_mismatch_rejected_at_build(self):
        topo = TopologyConfig(workers=3, up_gbps=(1.0, 2.0))
        with pytest.raises(ValueError, match="per-worker"):
            topo.build(default_workers=3)

    def test_validation_of_scalars(self):
        for bad in (dict(bandwidth_gbps=0), dict(shift_gbps=-1.0)):
            with pytest.raises(ValueError):
                NetworkConfig(**bad)
        for bad in (dict(servers=0), dict(workers=0),
                    dict(up_shift_factor=0.0)):
            with pytest.raises(ValueError):
                TopologyConfig(**bad)
        for bad in (dict(cost_source="psychic"), dict(remeasure_every=-1),
                    dict(measure_iters=0), dict(measure_warmup=-1),
                    dict(compute_flops_per_s=0)):
            with pytest.raises(ValueError):
                MeasureConfig(**bad)
        with pytest.raises(ValueError, match="strategy"):
            ScheduleConfig(strategy="psychic")
        with pytest.raises(ValueError, match="reschedule_every"):
            ScheduleConfig(reschedule_every=0)
        with pytest.raises(ValueError, match="throttle"):
            ExecutionConfig(throttle="drop")
        with pytest.raises(ValueError, match="optimizer"):
            RuntimeConfig(runtime="zero", optimizer="lion")


class TestFleetConfig:
    def test_round_trip_with_events(self):
        c = RuntimeConfig(
            runtime="fleet-async", **SMOKE,
            execution=ExecutionConfig(staleness=2, throttle="wait"),
            schedule=ScheduleConfig(topology=TopologyConfig(
                servers=2, workers=3)),
            fleet=FleetConfig(events=(
                FleetEventConfig(time=0.01, kind="join", worker=3,
                                 down_gbps=5.0, up_gbps=0.5),
                FleetEventConfig(time=0.03, kind="fail", worker=1,
                                 mode="stall"),
                FleetEventConfig(time=0.05, kind="drift", worker=0,
                                 factor=2.0),
            ), workers_per_shard=2, stall_factor=3.0))
        again = RuntimeConfig.from_json(c.to_json())
        assert again == c
        assert again.fleet.events[0].down_gbps == 5.0

    def test_round_trip_with_churn(self):
        c = RuntimeConfig(
            runtime="fleet-async", **SMOKE,
            fleet=FleetConfig(churn=2.0, horizon=1.5, churn_seed=7))
        assert RuntimeConfig.from_json(c.to_json()) == c

    def test_event_dicts_coerced(self):
        cfg = FleetConfig(events=(
            {"time": 0.1, "kind": "leave", "worker": 0},))
        assert isinstance(cfg.events[0], FleetEventConfig)
        assert cfg.events[0].kind == "leave"

    def test_fleet_field_needs_fleet_runtime(self):
        with pytest.raises(ValueError, match="fleet"):
            RuntimeConfig(runtime="ps-async", fleet=FleetConfig())

    def test_aggregate_rejected_on_fleet(self):
        with pytest.raises(ValueError, match="aggregate"):
            RuntimeConfig(runtime="fleet-async",
                          execution=ExecutionConfig(throttle="wait",
                                                    aggregate=True))

    def test_validation_of_scalars(self):
        for bad in (dict(churn=-1.0), dict(churn=1.0),  # churn w/o horizon
                    dict(churn=1.0, horizon=1.0,
                         events=(FleetEventConfig(time=0.1, kind="leave",
                                                  worker=0),)),
                    dict(stall_factor=1.0), dict(drift_alpha=0.0),
                    dict(drift_patience=0), dict(workers_per_shard=-1)):
            with pytest.raises(ValueError):
                FleetConfig(**bad)
        with pytest.raises(ValueError, match="join"):
            FleetEventConfig(time=0.1, kind="leave", worker=0,
                             down_gbps=5.0)
        with pytest.raises(ValueError, match="kind"):
            FleetEventConfig(time=0.1, kind="explode", worker=0)

    def test_build_schedule_and_detector(self):
        explicit = FleetConfig(events=(
            FleetEventConfig(time=0.1, kind="leave", worker=1),))
        sched = explicit.build_schedule((0, 1, 2))
        assert len(sched) == 1 and sched.events[0].kind == "leave"
        synth = FleetConfig(churn=4.0, horizon=2.0, churn_seed=3)
        a = synth.build_schedule(range(8))
        b = synth.build_schedule(range(8))
        assert a == b                    # seeded churn is reproducible
        det = FleetConfig(drift_threshold=0.5).build_detector()
        assert det.threshold == 0.5

    def test_fleet_runtime_regime_views(self):
        c = RuntimeConfig(runtime="fleet-async",
                          execution=ExecutionConfig(staleness=1))
        assert c.regime == "ps-async" and c.is_dynamic


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


class TestRegistry:
    def test_all_runtimes_registered(self):
        assert runtime_names() == ("dynamic", "dynamic-ps",
                                   "dynamic-ps-async", "fleet-async",
                                   "local", "pipeline", "ps", "ps-async",
                                   "zero")

    def test_register_unknown_name_rejected(self):
        from repro.runtime.registry import register_runtime
        with pytest.raises(ValueError, match="not a known name"):
            register_runtime("warp-speed")

    def test_duplicate_registration_rejected(self):
        from repro.runtime.registry import register_runtime
        runtime_names()                 # force adapter registration first
        with pytest.raises(ValueError, match="twice"):
            register_runtime("zero")(object)

    def test_bad_config_type_rejected(self):
        with pytest.raises(TypeError, match="config"):
            build_runtime(42)

    def test_bad_data_type_rejected(self):
        with pytest.raises(TypeError, match="data"):
            build_runtime(RuntimeConfig(runtime="local", **SMOKE), data=42)


# ---------------------------------------------------------------------------
# every registered runtime builds from its JSON smoke config and runs
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def built():
    """Lazily built runtimes, one per smoke config, shared module-wide."""
    cache = {}

    def get(runtime_name):
        if runtime_name not in cache:
            # a runtime may ship feature-variant configs alongside its
            # baseline (e.g. ps_async_int8.json) — pick the uncompressed one
            paths = sorted(p for p in smoke_config_paths()
                           if RuntimeConfig.load(p).runtime == runtime_name)
            assert paths, f"no smoke config for {runtime_name}"
            path = min(paths, key=lambda p:
                       RuntimeConfig.load(p).compression.enabled)
            cache[runtime_name] = (build_runtime(RuntimeConfig.load(path)),
                                   path)
        return cache[runtime_name]

    return get


LEDGER_KEYS = {"pull_bytes", "push_bytes", "num_pulls", "num_pushes"}


class TestEveryRuntime:
    @pytest.mark.parametrize("name", ["local", "zero", "ps", "ps-async",
                                      "dynamic", "dynamic-ps",
                                      "dynamic-ps-async", "fleet-async"])
    def test_builds_from_json_and_steps(self, built, name):
        rt, path = built(name)
        assert isinstance(rt, Trainer), f"{name} breaks the protocol"
        losses = rt.fit(2)
        assert len(losses) >= 2 and all(np.isfinite(losses))
        assert LEDGER_KEYS <= set(rt.ledger)
        assert isinstance(rt.config, RuntimeConfig)
        assert rt.config == RuntimeConfig.load(path)   # config preserved

    def test_dynamic_runtimes_reschedule_and_resegment(self, built):
        for name in ("dynamic", "dynamic-ps"):
            rt, _ = built(name)
            total = rt._data_idx
            rt.fit(4 - min(total, 4))           # reach the shift boundary
            assert len(rt.events) >= 2, name
            assert any(e.plan_changed for e in rt.events), \
                f"{name}: scripted drift must re-segment the plan"
            assert rt.timeline() is not None

    def test_zero_and_ps_share_one_loss_trajectory(self, built):
        """zero and ps run the same compute path (PSTrainer delegates to
        the ZeRO step) on the same data stream — losses are bit-identical
        even though their plans were derived from different cost models
        (losses are plan-independent, the test_dist invariant)."""
        l_zero = built("zero")[0].fit(1)[0]
        l_ps = built("ps")[0].fit(1)[0]
        assert l_zero == l_ps

    def test_ledger_accumulates(self, built):
        rt, _ = built("zero")
        before = rt.ledger["push_bytes"]
        rt.fit(1)
        after = rt.ledger["push_bytes"]
        assert after > before
        per_iter = after - before
        tb = rt.trainer
        from repro.dist.collectives import bucket_bytes
        want = sum(bucket_bytes(tb.specs, b) for b in tb.plan.backward) * \
            tb.axis_size
        assert per_iter == want

    def test_async_events_and_timeline(self, built):
        rt, _ = built("dynamic-ps-async")
        assert rt.timeline() is not None           # the cumulative log
        assert all(hasattr(e, "worker_plans") for e in rt.events)

    def test_step_with_explicit_batch(self, built):
        rt, _ = built("local")
        from repro.data.pipeline import SyntheticText
        pipe = SyntheticText(rt.arch.vocab_size, rt.config.seq,
                             rt.config.batch, seed=3)
        loss = rt.step(pipe.batch(0))
        assert np.isfinite(loss)


class TestSaveRestore:
    def test_dynamic_ps_resume_is_bit_identical(self, tmp_path):
        config = RuntimeConfig(
            runtime="dynamic-ps", **SMOKE,
            schedule=ScheduleConfig(reschedule_every=2,
                                    topology=TopologyConfig(
                                        servers=2, up_shift_factor=10.0,
                                        shift_epoch=1)))
        ref = build_runtime(config)
        ref_losses = ref.fit(6)
        a = build_runtime(config)
        first = a.fit(3)                          # stop mid-epoch
        path = str(tmp_path / "rt.npz")
        a.save_state(path)
        b = build_runtime(config)
        b.restore_state(path)
        rest = b.fit(3)
        assert first + rest == ref_losses
        # resume replays the same re-schedule history
        assert [(e.step, e.epoch, e.plan) for e in b.events] == \
            [(e.step, e.epoch, e.plan) for e in ref.events]

    def test_wrong_runtime_checkpoint_rejected(self, tmp_path, built):
        rt, _ = built("local")
        path = str(tmp_path / "local.npz")
        rt.save_state(path)
        other = built("zero")[0]
        with pytest.raises(ValueError, match="written by runtime"):
            other.restore_state(path)


class TestPeriodicCheckpoint:
    """fit(checkpoint_every=, checkpoint_path=) — the in-fit periodic
    checkpoint hook on the Trainer protocol."""

    def test_local_mid_run_resume_is_bit_identical(self, tmp_path):
        config = RuntimeConfig(runtime="local", **SMOKE)
        ref_losses = build_runtime(config).fit(5)
        path = str(tmp_path / "ck.npz")
        a = build_runtime(config)
        # the last periodic save lands at step 3 — the checkpoint is a
        # mid-run snapshot, not the final state
        a.fit(5, checkpoint_every=3, checkpoint_path=path)
        b = build_runtime(config)
        b.restore_state(path)
        assert b._data_idx == 3
        assert b.fit(2) == ref_losses[3:]

    def test_async_adapter_checkpoints_on_boundary(self, tmp_path):
        config = RuntimeConfig(
            runtime="ps-async", **SMOKE,
            execution=ExecutionConfig(staleness=1, throttle="wait"),
            schedule=ScheduleConfig(topology=TopologyConfig(servers=1,
                                                            workers=2)))
        path = str(tmp_path / "async.npz")
        rt = build_runtime(config)
        rt.fit(4, checkpoint_every=2, checkpoint_path=path)
        assert os.path.exists(path)
        restored = build_runtime(config)
        restored.restore_state(path)     # round-trips through save_state
        assert np.isfinite(restored.fit(1)[0])

    def test_checkpoint_validation(self, built):
        rt, _ = built("local")
        with pytest.raises(ValueError, match="checkpoint_path"):
            rt.fit(1, checkpoint_every=2)
        with pytest.raises(ValueError, match="checkpoint_every"):
            rt.fit(1, checkpoint_path="somewhere.npz")


# ---------------------------------------------------------------------------
# deprecation shims: old import paths + old hand-wired construction
# ---------------------------------------------------------------------------


class TestDeprecationShims:
    def test_moved_classes_warn_and_alias(self):
        import repro.dist.dynamic as dd
        from repro.runtime import replan
        for name in ("PlanStepCache", "RescheduleEvent"):
            with pytest.deprecated_call(match="moved to"):
                cls = getattr(dd, name)
            assert cls is getattr(replan, name)

    def test_unknown_attribute_still_raises(self):
        import repro.dist.dynamic as dd
        with pytest.raises(AttributeError):
            dd.does_not_exist

    def test_old_style_construction_matches_factory_losses(self, built):
        """The pre-registry wiring (hand-built DynamicTrainer, the old
        launch/train.py path) must produce losses bit-identical to the
        factory-built runtime on the same config."""
        from jax.sharding import Mesh
        from repro.configs import get_config
        from repro.core import bandwidth_shift
        from repro.data.pipeline import SyntheticText
        from repro.dist.dynamic import DynamicTrainer
        from repro.optim import adamw

        rt, path = built("dynamic")
        config = RuntimeConfig.load(path)
        cfg = get_config(config.arch).reduced()
        mesh = Mesh(np.array(jax.devices()).reshape(len(jax.devices()),),
                    ("data",))
        net_cfg = config.schedule.network
        old = DynamicTrainer(
            cfg=cfg, mesh=mesh, optimizer=adamw(config.lr),
            network=bandwidth_shift(net_cfg.bandwidth_gbps * 1e9,
                                    net_cfg.shift_gbps * 1e9,
                                    at_epoch=net_cfg.shift_epoch),
            steps_per_epoch=config.schedule.reschedule_every,
            strategy=config.schedule.strategy,
            input_shape=rt.shape,
            compute_flops_per_s=config.measure.compute_flops_per_s)
        state = old.init_state(jax.random.PRNGKey(config.seed))
        pipe = SyntheticText(cfg.vocab_size, config.seq, config.batch,
                             seed=config.seed)
        _, old_losses = old.run(state, pipe.batch, 4)
        new = build_runtime(config)             # fresh, same config
        assert new.fit(4) == old_losses


# ---------------------------------------------------------------------------
# SSP wait-throttle BSP aggregation (ISSUE 5 satellite)
# ---------------------------------------------------------------------------


def _cnn_loss(layers, batch):
    from repro.models.cnn import small_cnn_loss
    return small_cnn_loss({"layers": layers}, batch["images"],
                          batch["labels"])


def _fixed_batch(*_):
    r = np.random.default_rng(7)
    return {"images": jnp.asarray(r.normal(size=(8, 32, 32, 3)),
                                  jnp.float32),
            "labels": jnp.asarray(r.integers(0, 10, size=(8,)), jnp.int32)}


def _worker_batch(w, i):
    r = np.random.default_rng(100003 * w + i)
    return {"images": jnp.asarray(r.normal(size=(8, 32, 32, 3)),
                                  jnp.float32),
            "labels": jnp.asarray(r.integers(0, 10, size=(8,)), jnp.int32)}


def _agg_trainer(workers, *, aggregate, k=0, throttle="wait"):
    from repro.core import plan_from_decision
    from repro.models.cnn import small_cnn_init
    from repro.optim import sgd
    from repro.ps import AsyncPSTrainer, PSTopology, asymmetric_link
    params = small_cnn_init(jax.random.PRNGKey(0))
    L = len(params["layers"])
    plan = plan_from_decision(((1, 3), (4, L)), ((4, L), (1, 3)), L)
    topo = PSTopology(
        num_servers=2,
        links=tuple(asymmetric_link(10e9, 1e9) for _ in range(workers)),
        worker_flops=(1e10,) * workers)
    return AsyncPSTrainer(init_layers=params["layers"], loss_fn=_cnn_loss,
                          optimizer=sgd(0.05), topology=topo, plan=plan,
                          staleness=k, throttle=throttle,
                          aggregate=aggregate)


class TestBSPAggregation:
    def test_k0_aggregate_is_true_bsp(self):
        """k=0 wait+aggregate: one version bump per round of W pushes,
        zero staleness, nothing rejected, and — with identical per-worker
        data — losses bit-identical to the serialized single-worker run
        (aggregating W identical gradients and dividing by W is exact)."""
        agg = _agg_trainer(4, aggregate=True).run(12, _fixed_batch)
        solo = _agg_trainer(1, aggregate=False).run(3, _fixed_batch)
        heads = [e.result.version for e in agg.events]
        assert heads == [v for v in (1, 2, 3) for _ in range(4)]
        assert agg.max_staleness == 0
        assert agg.num_rejected == 0
        rounds = [agg.losses[i * 4:(i + 1) * 4] for i in range(3)]
        assert all(len(set(r)) == 1 for r in rounds), \
            "a BSP round sees one shared parameter version"
        assert [r[0] for r in rounds] == solo.losses

    def test_aggregate_distinct_batches_matches_host_bsp(self):
        """With distinct per-worker batches the aggregated trajectory is
        bit-identical to a hand-rolled BSP loop using the same grad_fn,
        flatten order, and mean (worker order, sum then divide)."""
        from repro.dist.collectives import flatten_tree, unflatten_tree
        tr = _agg_trainer(2, aggregate=True)
        log = tr.run(6, _worker_batch)           # 3 rounds of 2
        ref = _agg_trainer(2, aggregate=False)   # fresh server, same init
        sv, gf = ref.server, ref._grad_fn
        ref_losses = []
        for rnd in range(3):
            layers = [unflatten_tree(f, s)
                      for f, s in zip(sv.flats(), ref.specs)]
            pushes, losses = [], []
            for w in range(2):
                loss, grads = gf(layers, _worker_batch(w, rnd))
                losses.append(float(loss))
                full = {l: flatten_tree(grads[l], ref.specs[l])
                        for l in range(len(ref.specs))}
                pushes.append((w, rnd, full))
            sv.push_aggregated(pushes)
            ref_losses.extend(losses)
        assert log.losses == ref_losses

    def test_k0_aggregate_tracks_sync_ps_trainer(self):
        """The satellite's anchor: k=0 wait+aggregate with every worker
        on the full batch follows the synchronous PSTrainer on the same
        batch.  Comparison is to fp32 roundoff, not bitwise: PSTrainer's
        per-layer-VJP backward and the async whole-graph autodiff round
        differently (the documented ZeRO-vs-reference gap) — bit-identity
        is asserted against same-compute-path references above."""
        from jax.sharding import Mesh
        from repro.configs import get_config
        from repro.core.buckets import BucketPlan
        from repro.models import (init_params, num_sched_layers,
                                  params_from_sched_layers,
                                  sched_layer_trees, train_loss)
        from repro.optim import sgd
        from repro.ps import AsyncPSTrainer, PSTopology, PSTrainer

        cfg = get_config("granite-3-2b").reduced()
        Ls = num_sched_layers(cfg)
        mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
        plan = BucketPlan(forward=(tuple(range(Ls)),),
                          backward=(tuple(range(Ls - 1, -1, -1)),))
        sync = PSTrainer(cfg=cfg, mesh=mesh, plan=plan,
                         optimizer=sgd(0.05),
                         topology=PSTopology.uniform(2, 1))
        state = sync.init_state(jax.random.PRNGKey(0))
        step = jax.jit(sync.build_train_step())
        key = jax.random.PRNGKey(3)
        toks = jax.random.randint(key, (4, 16), 0, cfg.vocab_size)
        batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
        sync_losses = []
        for _ in range(3):
            state, loss = step(state, batch)
            sync_losses.append(float(loss))

        layers = sched_layer_trees(init_params(cfg, jax.random.PRNGKey(0)))

        def loss_fn(ls, b):
            return train_loss(cfg, params_from_sched_layers(ls), b,
                              aux_weight=0.01)

        atr = AsyncPSTrainer(init_layers=layers, loss_fn=loss_fn,
                             optimizer=sgd(0.05),
                             topology=PSTopology.uniform(2, 4),
                             plan=plan, staleness=0, throttle="wait",
                             aggregate=True)
        log = atr.run(12, lambda w, i: batch)    # 3 BSP rounds of 4
        round_losses = [log.losses[i * 4] for i in range(3)]
        np.testing.assert_allclose(round_losses, sync_losses, rtol=2e-5)

    def test_aggregate_requires_wait_throttle(self):
        with pytest.raises(ValueError, match="wait"):
            _agg_trainer(2, aggregate=True, throttle="reject")

    def test_aggregate_heterogeneous_fleet_still_lockstep(self):
        """Slower workers gate the round (BSP semantics): everyone
        contributes exactly once per round, fast workers accumulate
        barrier wait."""
        from repro.core import plan_from_decision
        from repro.models.cnn import small_cnn_init
        from repro.optim import sgd
        from repro.ps import AsyncPSTrainer, PSTopology, asymmetric_link
        params = small_cnn_init(jax.random.PRNGKey(0))
        L = len(params["layers"])
        plan = plan_from_decision(((1, L),), ((1, L),), L)
        topo = PSTopology(
            num_servers=1,
            links=tuple(asymmetric_link(10e9, 1e9) for _ in range(3)),
            worker_flops=(4e10, 4e10, 1e10))
        tr = AsyncPSTrainer(init_layers=params["layers"],
                            loss_fn=_cnn_loss, optimizer=sgd(0.05),
                            topology=topo, plan=plan, staleness=0,
                            throttle="wait", aggregate=True)
        log = tr.run(9, _worker_batch)
        assert log.accepted_by_worker() == {0: 3, 1: 3, 2: 3}
        assert log.max_staleness == 0
        assert log.total_wait_s > 0              # fast workers blocked
        assert log.num_rejected == 0

    def test_server_push_aggregated_validation(self):
        from repro.ps.server import PSServer
        from repro.dist.collectives import make_flat_spec, flatten_tree
        from repro.optim import sgd
        from repro.ps import PSTopology
        trees = [{"w": jnp.arange(4, dtype=jnp.float32)} for _ in range(2)]
        specs = [make_flat_spec(t, 1) for t in trees]
        flats = [flatten_tree(t, s) for t, s in zip(trees, specs)]
        sv = PSServer(specs, PSTopology.uniform(1, 2), sgd(0.1), flats,
                      staleness_bound=0)
        g = {l: jnp.ones((specs[l].padded,), jnp.float32)
             for l in range(2)}
        with pytest.raises(ValueError, match="empty"):
            sv.push_aggregated([])
        with pytest.raises(ValueError, match="one version"):
            sv.push_aggregated([(0, 0, g), (1, 1, g)])
        with pytest.raises(ValueError, match="lacks"):
            sv.push_aggregated([(0, 0, {0: g[0]})])
        res = sv.push_aggregated([(0, 0, g), (1, 0, g)])
        assert [r.accepted for r in res] == [True, True]
        assert sv.version == 1                   # one bump for the group
        # stale group: rejected atomically
        res = sv.push_aggregated([(0, 0, g)])
        assert not res[0].accepted and sv.ledger.rejected_pushes == 1


# ---------------------------------------------------------------------------
# measured per-worker fc/bc in the PS regime (ISSUE 5 satellite)
# ---------------------------------------------------------------------------


class TestMeasuredPSCosts:
    def test_topology_costs_measured_scales_per_worker(self):
        from repro.core.profiler import LayerProfile
        from repro.ps import PSTopology
        topo = PSTopology(num_servers=1,
                          links=PSTopology.uniform(1, 2).links,
                          worker_flops=(2e10, 5e9))
        profiles = [LayerProfile(name=f"l{i}", param_bytes=1e6,
                                 flops_fwd=1e9) for i in range(3)]
        fc = np.array([1e-3, 2e-3, 3e-3])
        bc = 2 * fc
        costs = topo.topology_costs_measured(profiles, fc=fc, bc=bc)
        # ref = fastest worker (2e10): its fc is the measurement as-is
        np.testing.assert_allclose(costs.workers[0].fc, fc)
        # the 4x-slower worker sees 4x the measured times
        np.testing.assert_allclose(costs.workers[1].fc, 4 * fc)
        np.testing.assert_allclose(costs.workers[1].bc, 4 * bc)
        # transmission still per-link analytic
        assert costs.workers[0].dt_push == topo.links[0].up.dt

    def test_topology_costs_measured_validation(self):
        from repro.core.profiler import LayerProfile
        from repro.ps import PSTopology
        topo = PSTopology.uniform(1, 1)
        profiles = [LayerProfile(name="l", param_bytes=1e6, flops_fwd=1e9)]
        with pytest.raises(ValueError, match="one entry per layer"):
            topo.topology_costs_measured(profiles, fc=[1e-3, 2e-3],
                                         bc=[1e-3, 2e-3])
        with pytest.raises(ValueError, match="ref_flops"):
            topo.topology_costs_measured(profiles, fc=[1e-3], bc=[1e-3],
                                         ref_flops=0.0)

    def test_dynamic_ps_measured_remeasures_on_schedule(self):
        """remeasure_every threads into DynamicPSTrainer the way the
        ZeRO-side DynamicTrainer already re-measures."""
        from jax.sharding import Mesh
        from repro.configs import get_config
        from repro.configs.base import InputShape
        from repro.data.pipeline import SyntheticText
        from repro.optim import adamw
        from repro.ps import DynamicPSTrainer, PSTopology

        cfg = get_config("granite-3-2b").reduced()
        mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
        pipe = SyntheticText(cfg.vocab_size, 16, 2, seed=0)
        dyn = DynamicPSTrainer(
            cfg=cfg, mesh=mesh, optimizer=adamw(1e-3),
            topology=PSTopology.uniform(2, 1),
            steps_per_epoch=2, input_shape=InputShape("m", 16, 2, "train"),
            cost_source="measured", remeasure_every=2,
            measure_iters=1, measure_warmup=0)
        state = dyn.init_state(jax.random.PRNGKey(0))
        state, losses = dyn.run(state, pipe.batch, 6)
        assert len(losses) == 6
        # epochs 0,1,2 re-planned; measurement at 0, re-measured at 2
        assert [e.epoch for e in dyn.events] == [0, 1, 2]
        assert dyn._measured_epoch == 2
        # the cached measurement feeds the cost projection
        costs = dyn.costs_for_epoch(0)
        np.testing.assert_allclose(np.asarray(costs.workers[0].fc),
                                   np.asarray(dyn._measured_fc_bc[0]))

    def test_measured_first_projection_needs_state_and_batch(self):
        from jax.sharding import Mesh
        from repro.configs import get_config
        from repro.configs.base import InputShape
        from repro.optim import adamw
        from repro.ps import DynamicPSTrainer, PSTopology
        cfg = get_config("granite-3-2b").reduced()
        mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
        dyn = DynamicPSTrainer(
            cfg=cfg, mesh=mesh, optimizer=adamw(1e-3),
            topology=PSTopology.uniform(2, 1), steps_per_epoch=2,
            input_shape=InputShape("m", 16, 2, "train"),
            cost_source="measured")
        with pytest.raises(ValueError, match="state and batch"):
            dyn.costs_for_epoch(0)

    def test_validation(self):
        from jax.sharding import Mesh
        from repro.configs import get_config
        from repro.configs.base import InputShape
        from repro.optim import adamw
        from repro.ps import DynamicPSTrainer, PSTopology
        cfg = get_config("granite-3-2b").reduced()
        mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
        kw = dict(cfg=cfg, mesh=mesh, optimizer=adamw(1e-3),
                  topology=PSTopology.uniform(2, 1), steps_per_epoch=2,
                  input_shape=InputShape("m", 16, 2, "train"))
        with pytest.raises(ValueError, match="cost_source"):
            DynamicPSTrainer(cost_source="psychic", **kw)
        with pytest.raises(ValueError, match="remeasure_every"):
            DynamicPSTrainer(remeasure_every=-1, **kw)
