"""Worker-side synchronous PS trainer.

``PSTrainer`` executes a ``BucketPlan`` in the parameter-server topology's
synchronous mode: every iteration, each worker pulls each forward
segment's parameters down (one transmission per segment), runs forward +
backward, and pushes each backward segment's gradients up (one
transmission per segment); the server applies the summed gradients and
all workers observe the new version at the barrier.

On the device mesh this maps exactly onto the bucketed ZeRO step: place
server shard *s*'s partition of every layer buffer on worker device *s*
(server shards co-located with workers, the standard sharded-PS
deployment), and a segment pull **is** one ``all-gather``, a segment push
**is** one ``reduce-scatter``, and the server-side optimizer apply **is**
the sharded update on local partitions.  ``PSTrainer`` therefore drives a
contained :class:`repro.dist.zero.ZeroTrainer` for the compiled data path
— which makes sync-mode losses *bit-identical* to the ZeRO trainer by
construction (asserted by ``tests/test_ps.py``) — and layers the PS
semantics on top: per-topology scheduling (per-worker fc/bc, per-link
asymmetric pt/gt/Δt), per-segment transfer accounting against the
topology's links, and the PS timeline view.

The compiled HLO carries exactly ``len(plan.forward)`` all-gathers and
``len(plan.backward)`` reduce-scatters — one pull + one push per segment,
2 transfers per (forward, backward) segment pair — for every scheduling
strategy.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

from jax.sharding import Mesh

from repro.configs.base import ArchConfig, InputShape
from repro.core.buckets import BucketPlan, decision_from_plan, \
    plan_from_decision
from repro.core.costmodel import TopologyCosts
from repro.core.scheduler import consensus_decision
from repro.core.simulator import PSTimeline, simulate_ps_iteration
from repro.dist.collectives import bucket_bytes
from repro.dist.zero import ZeroTrainer
from repro.models import model as model_lib
from repro.models.profiles import layer_profiles
from repro.optim import Optimizer
from repro.ps.topology import PSTopology


@dataclasses.dataclass
class PSTrainer:
    """Synchronous segmented-push/pull trainer over a PS topology."""

    cfg: ArchConfig
    mesh: Mesh
    plan: BucketPlan
    optimizer: Optimizer
    topology: PSTopology
    zero3: bool = False
    axis_name: str = "data"
    aux_weight: float = 0.01
    compressor: Optional[Any] = None

    def __post_init__(self):
        if self.compressor is not None and self.compressor.scheme == "none":
            self.compressor = None
        axis = int(self.mesh.shape[self.axis_name])
        if self.topology.num_workers != axis:
            raise ValueError(
                f"topology has {self.topology.num_workers} workers but the "
                f"mesh {self.axis_name!r} axis has {axis} devices — "
                f"synchronous PS runs one worker per device")
        # The compiled data path: co-located server shards make pull/push
        # ring collectives (module docstring) — delegate to the ZeRO step.
        self._zero = ZeroTrainer(cfg=self.cfg, mesh=self.mesh,
                                 plan=self.plan, optimizer=self.optimizer,
                                 zero3=self.zero3, axis_name=self.axis_name,
                                 aux_weight=self.aux_weight,
                                 compressor=self.compressor)
        self.specs = self._zero.specs
        self.num_layers = self._zero.num_layers

    # ------------------------------------------------------------------
    # construction from a topology (profile → per-worker plan → trainer)
    # ------------------------------------------------------------------

    @classmethod
    def from_topology(cls, cfg: ArchConfig, mesh: Mesh,
                      topology: PSTopology, optimizer: Optimizer,
                      input_shape: InputShape, *,
                      strategy: str = "dynacomm",
                      compressor: Optional[Any] = None,
                      **kwargs) -> "PSTrainer":
        """Schedule against the topology and build the trainer.

        Synchronous mode needs one shared plan; the consensus decision
        minimizes the straggler's iteration time (see
        ``core.scheduler.consensus_decision``).  A ``compressor`` is
        threaded into the plan search (pushes are timed on wire bytes, so
        the DP re-segments) and into the execution path."""
        topo_costs = topology.topology_costs(layer_profiles(cfg, input_shape),
                                             compressor=compressor)
        decision, _ = consensus_decision(topo_costs, strategy)
        plan = plan_from_decision(*decision, model_lib.num_sched_layers(cfg))
        return cls(cfg=cfg, mesh=mesh, plan=plan, optimizer=optimizer,
                   topology=topology, compressor=compressor, **kwargs)

    def with_plan(self, plan: BucketPlan) -> "PSTrainer":
        return dataclasses.replace(self, plan=plan)

    # ------------------------------------------------------------------
    # the compiled data path (delegated; see module docstring)
    # ------------------------------------------------------------------

    def init_state(self, key) -> Dict[str, Any]:
        return self._zero.init_state(key)

    def build_train_step(self):
        """jit-able ``step(state, batch) -> (state, mean_loss)`` carrying
        one pull + one push collective per plan segment."""
        return self._zero.build_train_step()

    def params_from_state(self, state) -> Any:
        return self._zero.params_from_state(state)

    # ------------------------------------------------------------------
    # PS accounting: segments → shards, bytes → links
    # ------------------------------------------------------------------

    @property
    def expected_transfers(self) -> Tuple[int, int]:
        """(pulls, pushes) per iteration == (all-gathers, reduce-scatters)
        in the compiled HLO: one of each per segment."""
        return (self.plan.num_forward_collectives,
                self.plan.num_backward_collectives)

    def segment_bytes(self, bucket) -> int:
        """Unpadded f32 payload of one segment's message."""
        return bucket_bytes(self.specs, bucket)

    def segment_owners(self) -> Dict[str, Tuple[int, ...]]:
        """Owning server shard per plan segment, both directions."""
        L = self.num_layers
        return {
            "forward": tuple(self.topology.owner_of_bucket(b, L)
                             for b in self.plan.forward),
            "backward": tuple(self.topology.owner_of_bucket(b, L)
                              for b in self.plan.backward),
        }

    def transfer_bytes(self) -> Dict[str, int]:
        """Per-iteration logical fp32 bytes each worker moves per
        direction."""
        return {
            "pull": sum(self.segment_bytes(b) for b in self.plan.forward),
            "push": sum(self.segment_bytes(b) for b in self.plan.backward),
        }

    def segment_wire_bytes(self, bucket) -> int:
        """Bytes one segment's push puts on the uplink (compressed
        per-layer payloads + per-segment header)."""
        if self.compressor is None:
            return self.segment_bytes(bucket)
        wire = sum(float(self.compressor.wire_bytes(self.specs[l].total * 4))
                   for l in bucket)
        return int(round(wire + self.compressor.segment_overhead_bytes))

    def transfer_wire_bytes(self) -> Dict[str, int]:
        """Per-iteration *wire* bytes per direction (pulls stay fp32)."""
        return {
            "pull": sum(self.segment_bytes(b) for b in self.plan.forward),
            "push": sum(self.segment_wire_bytes(b)
                        for b in self.plan.backward),
        }

    # ------------------------------------------------------------------
    # scheduling / simulation views
    # ------------------------------------------------------------------

    def topology_costs(self, input_shape: InputShape) -> TopologyCosts:
        return self.topology.topology_costs(
            layer_profiles(self.cfg, input_shape),
            compressor=self.compressor)

    def timeline_from_costs(self, costs: TopologyCosts) -> PSTimeline:
        """Per-worker timeline of one synchronous iteration of *this
        trainer's* plan under explicit costs (e.g. a topology epoch's
        projection a caller already holds), skipping the profile
        re-derivation that :meth:`timeline` performs."""
        return simulate_ps_iteration(costs, decision_from_plan(self.plan))

    def timeline(self, input_shape: InputShape) -> PSTimeline:
        """Per-worker timeline of one synchronous iteration of the plan."""
        return self.timeline_from_costs(self.topology_costs(input_shape))

    def estimated_step_seconds(self, input_shape: InputShape) -> float:
        return self.timeline(input_shape).makespan
