"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) combo.

The ONLY entry point that forges 512 host devices — the flag must be set
before any jax initialization, hence the first two lines.

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-2b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out results.jsonl]
"""

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import time
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHITECTURES, INPUT_SHAPES, get_config, shape_applicable
from repro.dist.sharding import (batch_shardings, cache_shardings,
                                 params_shardings)
from repro.launch.hlo_analysis import (collective_bytes, cost_analysis_dict,
                                       roofline)
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import decode_specs, input_specs, state_specs
from repro.models import model as model_lib
from repro.models import scanned
from repro.models.profiles import model_flops_per_token
from repro.optim import adamw
from repro.serve.decode import build_decode_step
from repro.train.loop import build_train_step

# Microbatched gradient accumulation for the biggest trainings (keeps the
# per-device activation footprint inside HBM; see DESIGN.md §5).
ACCUM_STEPS = {
    "grok-1-314b": 8,
    "llava-next-34b": 8,
    "gemma-7b": 4,
    "gemma3-4b": 2,
}


def _tokens_per_step(cfg, shape) -> float:
    if shape.mode == "decode":
        return shape.global_batch        # one token per sequence
    return shape.global_batch * shape.seq_len


def build_lowered(arch: str, shape_name: str, *, multi_pod: bool,
                  fsdp: bool = True, dtype=jnp.bfloat16,
                  accum: int | None = None, remat: bool = True,
                  cache_seq_over_model: bool = False, barrier: bool = False,
                  remat_sqrt: int = 0, moe_ep: bool = False):
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)

    if shape.mode == "train":
        opt = adamw(1e-4)
        params_s = jax.eval_shape(
            lambda k: scanned.init_stacked(cfg, k, dtype), jax.random.PRNGKey(0))
        opt_s = jax.eval_shape(opt.init, params_s)
        batch_s = input_specs(cfg, shape, dtype)
        if accum is None:
            accum = ACCUM_STEPS.get(arch, 1) if shape_name == "train_4k" else 1

        data_axes = tuple(a for a in mesh.axis_names if a != "model")
        d_entry = data_axes if len(data_axes) > 1 else data_axes[0]
        act_sh = NamedSharding(mesh, P(d_entry, None, None))
        logit_sh = NamedSharding(mesh, P(d_entry, None, "model"))

        def loss_fn(sp, batch):
            return scanned.train_loss_scanned(cfg, sp, batch, remat=remat,
                                              act_sharding=act_sh,
                                              logits_sharding=logit_sh,
                                              barrier=barrier,
                                              remat_sqrt=remat_sqrt)

        if accum == 1:
            def step(sp, opt_state, batch):
                loss, grads = jax.value_and_grad(loss_fn)(sp, batch)
                sp, opt_state = opt.update(grads, opt_state, sp)
                return sp, opt_state, loss
        else:
            def step(sp, opt_state, batch):
                def reshape(x):
                    return x.reshape((accum, x.shape[0] // accum) + x.shape[1:])
                micro = jax.tree_util.tree_map(reshape, batch)

                def body(carry, mb):
                    gacc, lacc = carry
                    loss, grads = jax.value_and_grad(loss_fn)(sp, mb)
                    gacc = jax.tree_util.tree_map(
                        lambda a, g: a + g.astype(jnp.float32), gacc, grads)
                    return (gacc, lacc + loss), None

                zeros = jax.tree_util.tree_map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), sp)
                (grads, lsum), _ = jax.lax.scan(body, (zeros, 0.0), micro)
                grads = jax.tree_util.tree_map(lambda g: g / accum, grads)
                sp, opt_state = opt.update(grads, opt_state, sp)
                return sp, opt_state, lsum / accum

        psh = params_shardings(cfg, params_s, mesh, fsdp=fsdp, moe_ep=moe_ep)
        osh = params_shardings(cfg, opt_s, mesh, fsdp=fsdp, moe_ep=moe_ep)
        bsh = batch_shardings(batch_s, mesh)
        jitted = jax.jit(step, in_shardings=(psh, osh, bsh),
                         out_shardings=(psh, osh, None))
        lowered = jitted.lower(params_s, opt_s, batch_s)

    elif shape.mode == "prefill":
        params_s = jax.eval_shape(
            lambda k: scanned.init_stacked(cfg, k, dtype), jax.random.PRNGKey(0))
        batch_s = input_specs(cfg, shape, dtype)

        data_axes = tuple(a for a in mesh.axis_names if a != "model")
        act_sh = NamedSharding(
            mesh, P(data_axes if len(data_axes) > 1 else data_axes[0],
                    None, None))

        def fn(sp, batch):
            logits, caches, _ = scanned.forward_scanned(
                cfg, sp, batch, mode="prefill", remat=False, last_only=True,
                act_sharding=act_sh)
            return logits, caches

        psh = params_shardings(cfg, params_s, mesh, fsdp=fsdp)
        bsh = batch_shardings(batch_s, mesh)
        jitted = jax.jit(fn, in_shardings=(psh, bsh))
        lowered = jitted.lower(params_s, batch_s)

    else:  # decode
        params_s, _ = state_specs(cfg, adamw(1e-4), dtype)
        token_s, caches_s = decode_specs(cfg, shape, dtype)
        psh = params_shardings(cfg, params_s, mesh, fsdp=fsdp)
        tsh = batch_shardings(token_s, mesh)
        csh = cache_shardings(caches_s, mesh, batch=shape.global_batch,
                              seq_over_model=cache_seq_over_model)
        step = build_decode_step(cfg)
        jitted = jax.jit(step, in_shardings=(psh, tsh, csh),
                         out_shardings=(None, csh))
        lowered = jitted.lower(params_s, token_s, caches_s)

    return lowered, mesh, cfg, shape


def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            fsdp: bool = True, verbose: bool = True, **kw) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    ok, reason = shape_applicable(cfg, shape)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "mode": shape.mode, "status": "skip" if not ok else "pending",
    }
    if not ok:
        rec["reason"] = reason
        if verbose:
            print(f"[skip] {arch} x {shape_name}: {reason}")
        return rec

    t0 = time.perf_counter()
    try:
        lowered, mesh, cfg, shape = build_lowered(
            arch, shape_name, multi_pod=multi_pod, fsdp=fsdp, **kw)
        t_lower = time.perf_counter() - t0
        t0 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0

        mem = compiled.memory_analysis()
        cost = cost_analysis_dict(compiled)
        hlo = compiled.as_text()
        coll = collective_bytes(hlo)
        chips = mesh.devices.size

        flops = float(cost.get("flops", 0.0))
        bytes_acc = float(cost.get("bytes accessed", 0.0))
        rl = roofline(flops=flops, hbm_bytes=bytes_acc, coll=coll, chips=chips)

        model_fl = model_flops_per_token(cfg) * _tokens_per_step(cfg, shape)
        if shape.mode != "train":
            model_fl /= 3.0          # forward only (no 2x backward)

        rec.update({
            "status": "ok",
            "chips": chips,
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "memory": {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "generated_code_bytes":
                    getattr(mem, "generated_code_size_in_bytes", None),
            },
            "flops_per_device": flops,
            "hbm_bytes_per_device": bytes_acc,
            "collective_bytes_per_device": rl.coll_bytes,
            "collective_detail": {k: v for k, v in coll.items()
                                  if not k.startswith("_")},
            "collective_counts": coll["_counts"],
            "roofline": {
                "compute_s": rl.compute_s,
                "memory_s": rl.memory_s,
                "collective_s": rl.collective_s,
                "dominant": rl.dominant,
            },
            "model_flops_global": model_fl,
            "model_flops_per_device": model_fl / chips,
            "useful_flop_ratio":
                (model_fl / chips) / flops if flops else None,
        })
        if verbose:
            r = rec["roofline"]
            print(f"[ok] {arch} x {shape_name} x {mesh_name}: "
                  f"compile {t_compile:.1f}s | "
                  f"compute {r['compute_s']:.3e}s mem {r['memory_s']:.3e}s "
                  f"coll {r['collective_s']:.3e}s -> {r['dominant']}-bound | "
                  f"temp {rec['memory']['temp_bytes']}")
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec.update({"status": "error", "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-2000:]})
        if verbose:
            print(f"[ERROR] {arch} x {shape_name} x {mesh_name}: {e}")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHITECTURES), default=None)
    ap.add_argument("--shape", choices=sorted(INPUT_SHAPES), default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--cache-seq-over-model", action="store_true")
    ap.add_argument("--barrier", action="store_true")
    ap.add_argument("--remat-sqrt", type=int, default=0)
    ap.add_argument("--moe-ep", action="store_true")
    ap.add_argument("--accum", type=int, default=None)
    ap.add_argument("--out", default=None, help="append JSONL records here")
    args = ap.parse_args()

    combos = []
    archs = sorted(ARCHITECTURES) if (args.all or not args.arch) else [args.arch]
    shapes = sorted(INPUT_SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                combos.append((arch, shape, mp))

    for arch, shape, mp in combos:
        rec = run_one(arch, shape, multi_pod=mp, fsdp=not args.no_fsdp,
                      accum=args.accum, barrier=args.barrier,
                      remat_sqrt=args.remat_sqrt, moe_ep=args.moe_ep,
                      cache_seq_over_model=args.cache_seq_over_model)
        if args.out:
            with open(args.out, "a") as f:
                f.write(json.dumps(rec) + "\n")


if __name__ == "__main__":
    main()
