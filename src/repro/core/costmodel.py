"""Cost model for layer-wise communication scheduling (DynaComm, JSAC'21).

Implements the paper's Section III formulation:

* every iteration is four procedures ``[pt, fc, bc, gt]`` decomposable into
  L per-layer mini-procedures;
* a *decision* partitions the L layers into contiguous transmission segments
  (forward: increasing layer order for parameter pulls; backward: decreasing
  layer order for gradient pushes);
* every transmission mini-procedure pays a fixed overhead ``dt`` (the paper's
  ``Δt``);
* ``f_m`` evaluates the end-to-end time of a decision in O(L) (the paper's
  "approximate cost measurement function", eq. 8).

Layers are 1-indexed in the paper; here cost vectors are 0-indexed numpy
arrays where index ``l-1`` holds layer ``l``'s cost.  Decisions are stored in
the canonical *segment* form — the zero-one vectors ``p`` / ``g`` of the
paper's ZOIP formulation are provided as conversions.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

import numpy as np

Segment = Tuple[int, int]  # (lo, hi) 1-indexed inclusive layer range


@dataclasses.dataclass(frozen=True)
class LayerCosts:
    """Per-layer cost vectors and the per-transmission overhead Δt.

    pt: parameter-transmission cost per layer (seconds)
    fc: forward-computation cost per layer
    bc: backward-computation cost per layer
    gt: gradient-transmission cost per layer
    dt: fixed overhead per transmission mini-procedure (Δt)
    dt_bwd: optional distinct Δt for the backward (push) direction — an
        asymmetric link (parameter-server downlink vs uplink) pays different
        setup costs per direction.  ``None`` means symmetric (= ``dt``).
    """

    pt: np.ndarray
    fc: np.ndarray
    bc: np.ndarray
    gt: np.ndarray
    dt: float
    dt_bwd: float | None = None

    def __post_init__(self):
        for name in ("pt", "fc", "bc", "gt"):
            arr = np.asarray(getattr(self, name), dtype=np.float64)
            object.__setattr__(self, name, arr)
            if arr.ndim != 1:
                raise ValueError(f"{name} must be 1-D, got shape {arr.shape}")
            if arr.shape[0] != self.pt.shape[0]:
                raise ValueError("all cost vectors must share length L")
            if np.any(arr < 0):
                raise ValueError(f"{name} has negative costs")
        if self.dt < 0:
            raise ValueError("dt must be non-negative")
        if self.dt_bwd is not None and self.dt_bwd < 0:
            raise ValueError("dt_bwd must be non-negative")

    @property
    def num_layers(self) -> int:
        return int(self.pt.shape[0])

    @property
    def dt_push(self) -> float:
        """Δt of a gradient push (backward direction); ``dt`` if symmetric."""
        return self.dt if self.dt_bwd is None else self.dt_bwd

    @property
    def idle_window(self) -> float:
        """The Δt + gt¹ window (paper Table I): while layer 1's gradient
        push — always the last transmission of an iteration — is in
        flight, the worker's compute is idle and the forward scheduler for
        iteration i+1 can run hidden."""
        return self.dt_push + float(self.gt[0])

    def scaled(self, *, compute: float = 1.0, comm: float = 1.0,
               dt: float | None = None,
               dt_bwd: float | None = None) -> "LayerCosts":
        """Return a copy with compute / communication costs rescaled.

        Used by the sensitivity studies (paper Fig. 9): ``compute`` scales
        fc/bc (∝ batch size), ``comm`` scales pt/gt (∝ 1/bandwidth).

        Overriding ``dt`` alone yields a *symmetric* copy (any ``dt_bwd``
        of the original is dropped — the Δt sweeps study one overhead
        knob); pass ``dt_bwd`` too to set the push direction explicitly.
        """
        if dt_bwd is not None and dt is None:
            raise ValueError("dt_bwd override requires dt")
        return LayerCosts(
            pt=self.pt * comm,
            fc=self.fc * compute,
            bc=self.bc * compute,
            gt=self.gt * comm,
            dt=self.dt if dt is None else dt,
            dt_bwd=self.dt_bwd if dt is None else dt_bwd,
        )

    def compressed(self, *, gt_ratio: float = 1.0, pt_ratio: float = 1.0,
                   dt_bwd_extra: float = 0.0) -> "LayerCosts":
        """Costs under wire compression: transmissions shrink by the given
        ratios (compute untouched) while every push pays an extra
        per-segment header cost ``dt_bwd_extra`` (e.g. top-k index/length
        metadata), folded into Δt of the backward direction.

        This is the generic ratio view for sweeps and property tests;
        ``PSTopology.topology_costs(..., compressor=)`` computes the exact
        per-layer wire bytes instead.
        """
        for name, ratio in (("gt_ratio", gt_ratio), ("pt_ratio", pt_ratio)):
            if not 0.0 < ratio <= 1.0:
                raise ValueError(f"{name} must be in (0, 1], got {ratio}")
        if dt_bwd_extra < 0:
            raise ValueError("dt_bwd_extra must be non-negative")
        return LayerCosts(
            pt=self.pt * pt_ratio,
            fc=self.fc,
            bc=self.bc,
            gt=self.gt * gt_ratio,
            dt=self.dt,
            dt_bwd=self.dt_push + dt_bwd_extra,
        )


# ---------------------------------------------------------------------------
# Decision representations
# ---------------------------------------------------------------------------


def validate_forward_segments(segments: Sequence[Segment], L: int) -> None:
    """Forward segments must tile [1..L] in increasing order."""
    if not segments:
        raise ValueError("empty decision")
    expect = 1
    for lo, hi in segments:
        if lo != expect or hi < lo:
            raise ValueError(f"invalid forward segments {segments} for L={L}")
        expect = hi + 1
    if expect != L + 1:
        raise ValueError(f"forward segments {segments} do not cover 1..{L}")


def validate_backward_segments(segments: Sequence[Segment], L: int) -> None:
    """Backward segments must tile [L..1] in decreasing order.

    Stored as (lo, hi) inclusive; transmission order is the list order, so
    the first element contains layer L and the last contains layer 1.
    """
    if not segments:
        raise ValueError("empty decision")
    expect = L
    for lo, hi in segments:
        if hi != expect or hi < lo:
            raise ValueError(f"invalid backward segments {segments} for L={L}")
        expect = lo - 1
    if expect != 0:
        raise ValueError(f"backward segments {segments} do not cover {L}..1")


def forward_segments_from_p(p: Sequence[int]) -> Tuple[Segment, ...]:
    """Paper ZOIP vector p (length L-1; p[l-1]=1 enables the cut after layer l)."""
    L = len(p) + 1
    segs, lo = [], 1
    for l, bit in enumerate(p, start=1):
        if bit:
            segs.append((lo, l))
            lo = l + 1
    segs.append((lo, L))
    return tuple(segs)


def p_from_forward_segments(segments: Sequence[Segment]) -> Tuple[int, ...]:
    L = segments[-1][1]
    cuts = {hi for _, hi in segments if hi != L}
    return tuple(1 if l in cuts else 0 for l in range(1, L))


def backward_segments_from_g(g: Sequence[int]) -> Tuple[Segment, ...]:
    """Paper vector g (g[l-1]=1 enables the cut after layer L+1-l, backward order)."""
    L = len(g) + 1
    segs, hi = [], L
    for l, bit in enumerate(g, start=1):
        if bit:
            lo = L + 1 - l
            segs.append((lo, hi))
            hi = lo - 1
    segs.append((1, hi))
    return tuple(segs)


def g_from_backward_segments(segments: Sequence[Segment]) -> Tuple[int, ...]:
    L = segments[0][1]
    cuts = {lo for lo, _ in segments if lo != 1}  # cut sits after layer lo (downward)
    return tuple(1 if (L + 1 - l) in cuts else 0 for l in range(1, L))


def singleton_segments_forward(L: int) -> Tuple[Segment, ...]:
    return tuple((l, l) for l in range(1, L + 1))


def singleton_segments_backward(L: int) -> Tuple[Segment, ...]:
    return tuple((l, l) for l in range(L, 0, -1))


# ---------------------------------------------------------------------------
# f_m — the O(L) cost measurement function (paper eq. 8)
# ---------------------------------------------------------------------------


def forward_time(costs: LayerCosts, segments: Sequence[Segment]) -> float:
    """End time of the last forward-compute mini-procedure.

    Transmissions are serialized on the link and launched back-to-back
    (all parameters are available server-side at t=0); a segment's compute
    starts once (a) its parameters have arrived and (b) the previous
    segment's compute finished — exactly the partial orders of eqs. (1),
    (4), (5).
    """
    validate_forward_segments(segments, costs.num_layers)
    t_comm = 0.0
    t_comp = 0.0
    for lo, hi in segments:
        t_comm += costs.dt + float(np.sum(costs.pt[lo - 1:hi]))
        t_comp = max(t_comp, t_comm) + float(np.sum(costs.fc[lo - 1:hi]))
    return t_comp


def backward_time(costs: LayerCosts, segments: Sequence[Segment]) -> float:
    """End time of the last gradient-transmission mini-procedure.

    Backward compute runs layer L → 1 without stalls; a segment's gradients
    are pushed once (a) its layers' backward compute is done and (b) the
    link is free — eqs. (2), (6), (7).
    """
    validate_backward_segments(segments, costs.num_layers)
    t_comp = 0.0
    t_comm = 0.0
    for lo, hi in segments:
        t_comp += float(np.sum(costs.bc[lo - 1:hi]))
        t_comm = max(t_comm, t_comp) + costs.dt_push \
            + float(np.sum(costs.gt[lo - 1:hi]))
    return t_comm


def iteration_time(costs: LayerCosts,
                   fwd_segments: Sequence[Segment],
                   bwd_segments: Sequence[Segment]) -> float:
    """Total iteration time: forward phase then backward phase (eq. 3 chains
    them — bc_L cannot start before fc_L ends)."""
    return forward_time(costs, fwd_segments) + backward_time(costs, bwd_segments)


# ---------------------------------------------------------------------------
# Per-topology costs (parameter-server regime: W workers, each with its own
# compute rate and its own asymmetric link to the server shards)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TopologyCosts:
    """One ``LayerCosts`` per worker of a PS topology.

    The cluster-level ``LayerCosts`` models one homogeneous worker pool
    behind one link; a PS topology has per-worker fc/bc (heterogeneous edge
    hardware) and per-link pt/gt/Δt (asymmetric, per-worker up/down paths),
    so DynaComm must plan per worker — or pick one shared plan that
    minimizes the synchronous straggler (see
    ``repro.core.scheduler.consensus_decision``).
    """

    workers: Tuple[LayerCosts, ...]

    def __post_init__(self):
        workers = tuple(self.workers)
        object.__setattr__(self, "workers", workers)
        if not workers:
            raise ValueError("TopologyCosts needs at least one worker")
        Ls = {c.num_layers for c in workers}
        if len(Ls) != 1:
            raise ValueError(f"workers disagree on layer count: {sorted(Ls)}")

    @property
    def num_workers(self) -> int:
        return len(self.workers)

    @property
    def num_layers(self) -> int:
        return self.workers[0].num_layers

    def iteration_times(self, fwd_segments: Sequence[Segment],
                        bwd_segments: Sequence[Segment]) -> Tuple[float, ...]:
        """Per-worker iteration time under one shared decision."""
        return tuple(iteration_time(c, fwd_segments, bwd_segments)
                     for c in self.workers)

    def makespan(self, fwd_segments: Sequence[Segment],
                 bwd_segments: Sequence[Segment]) -> float:
        """Synchronous-mode iteration time: the straggler's finish."""
        return max(self.iteration_times(fwd_segments, bwd_segments))

    def straggler(self, fwd_segments: Sequence[Segment],
                  bwd_segments: Sequence[Segment]) -> int:
        """Index of the worker that gates the synchronous barrier."""
        times = self.iteration_times(fwd_segments, bwd_segments)
        return int(np.argmax(times))

    @property
    def idle_window(self) -> float:
        """The topology-wide Δt + gt¹ idle window: the re-plan must be
        hidden for *every* worker (the scheduler cannot know which worker
        will straggle next epoch), so the binding window is the minimum
        over workers."""
        return min(c.idle_window for c in self.workers)

    def scaled(self, *, compute: float = 1.0, comm: float = 1.0
               ) -> "TopologyCosts":
        """Every worker's costs rescaled uniformly (sensitivity sweeps:
        ``comm`` ∝ 1/bandwidth on all links, ``compute`` ∝ batch size)."""
        return TopologyCosts(workers=tuple(
            c.scaled(compute=compute, comm=comm) for c in self.workers))

    def compressed(self, *, gt_ratio: float = 1.0, pt_ratio: float = 1.0,
                   dt_bwd_extra: float = 0.0) -> "TopologyCosts":
        """Every worker's costs under wire compression (see
        ``LayerCosts.compressed``)."""
        return TopologyCosts(workers=tuple(
            c.compressed(gt_ratio=gt_ratio, pt_ratio=pt_ratio,
                         dt_bwd_extra=dt_bwd_extra) for c in self.workers))


# ---------------------------------------------------------------------------
# Breakdown used by the paper's stacked-bar figures (Figs. 5-8)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PhaseBreakdown:
    total: float
    comm_busy: float          # link busy time
    comp_busy: float          # compute busy time
    overlap: float            # time both are busy
    comm_only: float          # non-overlapping communication
    comp_only: float          # non-overlapping computation
    idle: float               # neither busy (possible between segments)


def _busy_union(intervals):
    """Total measure of a union of [s, e) intervals."""
    if not intervals:
        return 0.0
    ivs = sorted(intervals)
    total, cur_s, cur_e = 0.0, ivs[0][0], ivs[0][1]
    for s, e in ivs[1:]:
        if s > cur_e:
            total += cur_e - cur_s
            cur_s, cur_e = s, e
        else:
            cur_e = max(cur_e, e)
    return total + (cur_e - cur_s)


def _intersection(a, b):
    """Measure of intersection of two interval unions."""
    pts = []
    for s, e in a:
        pts.append((s, 0, 1))
        pts.append((e, 0, -1))
    for s, e in b:
        pts.append((s, 1, 1))
        pts.append((e, 1, -1))
    pts.sort()
    depth = [0, 0]
    last = None
    total = 0.0
    for t, which, d in pts:
        if last is not None and depth[0] > 0 and depth[1] > 0:
            total += t - last
        depth[which] += d
        last = t
    return total


def phase_breakdown(comm_intervals, comp_intervals) -> PhaseBreakdown:
    comm_busy = _busy_union(comm_intervals)
    comp_busy = _busy_union(comp_intervals)
    overlap = _intersection(comm_intervals, comp_intervals)
    ends = [e for _, e in comm_intervals] + [e for _, e in comp_intervals]
    starts = [s for s, _ in comm_intervals] + [s for s, _ in comp_intervals]
    total = (max(ends) - min(starts)) if ends else 0.0
    comm_only = comm_busy - overlap
    comp_only = comp_busy - overlap
    idle = total - comm_only - comp_only - overlap
    return PhaseBreakdown(total=total, comm_busy=comm_busy, comp_busy=comp_busy,
                          overlap=overlap, comm_only=comm_only,
                          comp_only=comp_only, idle=max(idle, 0.0))
