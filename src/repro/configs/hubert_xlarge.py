"""hubert-xlarge [arXiv:2106.07447] — encoder-only, wav2vec2-style backbone.

The conv/mel frontend is a stub supplying precomputed frame embeddings.
No decode step exists for this architecture (see DESIGN.md skip notes).
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="hubert-xlarge",
    family="audio",
    citation="arXiv:2106.07447",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    d_ff=5120,
    vocab_size=504,
    activation="gelu",
    gated_mlp=False,
    causal=False,
    encoder_only=True,
    frontend="audio",
    tie_embeddings=False,
)
