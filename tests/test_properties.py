"""System-invariant property tests (hypothesis)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (LayerCosts, backward_time, dp_backward, dp_forward,
                        forward_time)
from repro.core.baselines import lbl_backward, lbl_forward
from repro.core.costmodel import (backward_segments_from_g,
                                  forward_segments_from_p,
                                  g_from_backward_segments,
                                  p_from_forward_segments)


def _mk(pt, fc, bc, gt, dt):
    return LayerCosts(pt=np.array(pt), fc=np.array(fc), bc=np.array(bc),
                      gt=np.array(gt), dt=dt)


vec = lambda L: st.lists(st.floats(0.0, 100.0), min_size=L, max_size=L)
inst = st.integers(2, 8).flatmap(
    lambda L: st.tuples(vec(L), vec(L), vec(L), vec(L), st.floats(0.0, 10.0)))


class TestSchedulingInvariants:
    @settings(max_examples=100, deadline=None)
    @given(inst, st.floats(0.1, 10.0))
    def test_optimum_scales_linearly(self, tup, lam):
        """T*(λ·costs) == λ·T*(costs) — the objective is 1-homogeneous."""
        pt, fc, bc, gt, dt = tup
        c1 = _mk(pt, fc, bc, gt, dt)
        c2 = c1.scaled(compute=lam, comm=lam, dt=lam * dt)
        t1 = dp_forward(c1).time
        t2 = dp_forward(c2).time
        assert t2 == pytest.approx(lam * t1, rel=1e-9, abs=1e-9)

    @settings(max_examples=100, deadline=None)
    @given(inst)
    def test_zero_dt_makes_lbl_optimal(self, tup):
        """With Δt = 0, splitting a segment never hurts ⇒ LBL is optimal."""
        pt, fc, bc, gt, _ = tup
        c = _mk(pt, fc, bc, gt, 0.0)
        L = c.num_layers
        assert forward_time(c, lbl_forward(L)) == pytest.approx(
            dp_forward(c).time, rel=1e-9, abs=1e-9)
        assert backward_time(c, lbl_backward(L)) == pytest.approx(
            dp_backward(c).time, rel=1e-9, abs=1e-9)

    @settings(max_examples=100, deadline=None)
    @given(inst)
    def test_forward_backward_duality(self, tup):
        """The backward problem is the forward problem under time reversal:
        reversing a backward schedule turns the push of the last segment
        into the first pull, so T*_bwd(bc, gt) == T*_fwd(pt=gt, fc=bc)
        (indices unreversed — layer 1's push, executed last, maps to
        layer 1's pull, executed first)."""
        pt, fc, bc, gt, dt = tup
        c = _mk(pt, fc, bc, gt, dt)
        dual = _mk(gt, bc, bc, gt, dt)
        assert dp_backward(c).time == pytest.approx(
            dp_forward(dual).time, rel=1e-9, abs=1e-9)

    @settings(max_examples=100, deadline=None)
    @given(inst, st.floats(0.0, 5.0))
    def test_dt_monotone(self, tup, extra):
        """Raising Δt can never reduce the optimal time."""
        pt, fc, bc, gt, dt = tup
        c1 = _mk(pt, fc, bc, gt, dt)
        c2 = _mk(pt, fc, bc, gt, dt + extra)
        assert dp_forward(c2).time >= dp_forward(c1).time - 1e-9

    @settings(max_examples=100, deadline=None)
    @given(inst)
    def test_lower_bounds(self, tup):
        """T*_fwd ≥ max(total compute, Δt + total comm) — either stream is
        a lower bound."""
        pt, fc, bc, gt, dt = tup
        c = _mk(pt, fc, bc, gt, dt)
        t = dp_forward(c).time
        assert t >= float(np.sum(c.fc)) - 1e-9
        assert t >= dt + float(np.sum(c.pt)) - 1e-9


class TestDecisionEncodings:
    @settings(max_examples=100, deadline=None)
    @given(st.integers(1, 12).flatmap(
        lambda L: st.lists(st.integers(0, 1), min_size=L - 1, max_size=L - 1)))
    def test_p_roundtrip(self, p):
        p = tuple(p)
        assert p_from_forward_segments(forward_segments_from_p(p)) == p

    @settings(max_examples=100, deadline=None)
    @given(st.integers(1, 12).flatmap(
        lambda L: st.lists(st.integers(0, 1), min_size=L - 1, max_size=L - 1)))
    def test_g_roundtrip(self, g):
        g = tuple(g)
        assert g_from_backward_segments(backward_segments_from_g(g)) == g
