"""System-invariant property tests (hypothesis).

Runs under the real `hypothesis` package when installed (CI) or the
deterministic fallback in ``repro._compat.hypothesis_fallback`` (installed
by conftest.py when the import fails) — both execute every ``@given`` test
against randomized instances, so the strategies stick to the shared API
surface (floats/integers/lists/tuples/booleans + map/flatmap).
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (LayerCosts, TopologyCosts, backward_time,
                        bruteforce_backward, bruteforce_forward, dp_backward,
                        dp_forward, forward_time, ibatch_backward,
                        iteration_time, simulate_ps_iteration)
from repro.core.baselines import lbl_backward, lbl_forward
from repro.core.costmodel import (backward_segments_from_g,
                                  forward_segments_from_p,
                                  g_from_backward_segments,
                                  p_from_forward_segments,
                                  validate_backward_segments)


def _mk(pt, fc, bc, gt, dt, dt_bwd=None):
    return LayerCosts(pt=np.array(pt), fc=np.array(fc), bc=np.array(bc),
                      gt=np.array(gt), dt=dt, dt_bwd=dt_bwd)


vec = lambda L: st.lists(st.floats(0.0, 100.0), min_size=L, max_size=L)
inst = st.integers(2, 8).flatmap(
    lambda L: st.tuples(vec(L), vec(L), vec(L), vec(L), st.floats(0.0, 10.0)))
# instance + a possibly-asymmetric push overhead: (tup, dt_bwd, asymmetric?)
inst_asym = st.tuples(inst, st.floats(0.0, 10.0), st.booleans())


class TestSchedulingInvariants:
    @settings(max_examples=100, deadline=None)
    @given(inst, st.floats(0.1, 10.0))
    def test_optimum_scales_linearly(self, tup, lam):
        """T*(λ·costs) == λ·T*(costs) — the objective is 1-homogeneous."""
        pt, fc, bc, gt, dt = tup
        c1 = _mk(pt, fc, bc, gt, dt)
        c2 = c1.scaled(compute=lam, comm=lam, dt=lam * dt)
        t1 = dp_forward(c1).time
        t2 = dp_forward(c2).time
        assert t2 == pytest.approx(lam * t1, rel=1e-9, abs=1e-9)

    @settings(max_examples=100, deadline=None)
    @given(inst)
    def test_zero_dt_makes_lbl_optimal(self, tup):
        """With Δt = 0, splitting a segment never hurts ⇒ LBL is optimal."""
        pt, fc, bc, gt, _ = tup
        c = _mk(pt, fc, bc, gt, 0.0)
        L = c.num_layers
        assert forward_time(c, lbl_forward(L)) == pytest.approx(
            dp_forward(c).time, rel=1e-9, abs=1e-9)
        assert backward_time(c, lbl_backward(L)) == pytest.approx(
            dp_backward(c).time, rel=1e-9, abs=1e-9)

    @settings(max_examples=100, deadline=None)
    @given(inst)
    def test_forward_backward_duality(self, tup):
        """The backward problem is the forward problem under time reversal:
        reversing a backward schedule turns the push of the last segment
        into the first pull, so T*_bwd(bc, gt) == T*_fwd(pt=gt, fc=bc)
        (indices unreversed — layer 1's push, executed last, maps to
        layer 1's pull, executed first)."""
        pt, fc, bc, gt, dt = tup
        c = _mk(pt, fc, bc, gt, dt)
        dual = _mk(gt, bc, bc, gt, dt)
        assert dp_backward(c).time == pytest.approx(
            dp_forward(dual).time, rel=1e-9, abs=1e-9)

    @settings(max_examples=100, deadline=None)
    @given(inst, st.floats(0.0, 5.0))
    def test_dt_monotone(self, tup, extra):
        """Raising Δt can never reduce the optimal time."""
        pt, fc, bc, gt, dt = tup
        c1 = _mk(pt, fc, bc, gt, dt)
        c2 = _mk(pt, fc, bc, gt, dt + extra)
        assert dp_forward(c2).time >= dp_forward(c1).time - 1e-9

    @settings(max_examples=100, deadline=None)
    @given(inst)
    def test_lower_bounds(self, tup):
        """T*_fwd ≥ max(total compute, Δt + total comm) — either stream is
        a lower bound."""
        pt, fc, bc, gt, dt = tup
        c = _mk(pt, fc, bc, gt, dt)
        t = dp_forward(c).time
        assert t >= float(np.sum(c.fc)) - 1e-9
        assert t >= dt + float(np.sum(c.pt)) - 1e-9


class TestOptimalityOracle:
    """The DP against the exhaustive 2^(L-1) search (ISSUE 4 satellite)."""

    @settings(max_examples=100, deadline=None)
    @given(inst)
    def test_dp_forward_matches_bruteforce(self, tup):
        pt, fc, bc, gt, dt = tup
        c = _mk(pt, fc, bc, gt, dt)
        segs, t = bruteforce_forward(c)
        res = dp_forward(c)
        assert res.time == pytest.approx(t, rel=1e-9, abs=1e-9)
        # and the DP's reported time is the f_m of its own segments
        assert res.time == pytest.approx(forward_time(c, res.segments),
                                         rel=1e-9, abs=1e-9)

    @settings(max_examples=100, deadline=None)
    @given(inst_asym)
    def test_dp_backward_matches_bruteforce(self, tup):
        """Including asymmetric Δt_bwd: the backward DP's objective must
        stay exact when a push pays a different per-transmission overhead
        than a pull (the PS uplink regime)."""
        (pt, fc, bc, gt, dt), dt_bwd, asym = tup
        c = _mk(pt, fc, bc, gt, dt, dt_bwd=dt_bwd if asym else None)
        segs, t = bruteforce_backward(c)
        res = dp_backward(c)
        assert res.time == pytest.approx(t, rel=1e-9, abs=1e-9)
        assert res.time == pytest.approx(backward_time(c, res.segments),
                                         rel=1e-9, abs=1e-9)

    @settings(max_examples=100, deadline=None)
    @given(inst_asym)
    def test_ibatch_backward_is_valid_and_lower_bounded(self, tup):
        """iBatch's greedy is *documented* to land in local optima
        (``core.greedy``: the greedy choice property does not hold, paper
        Fig. 5(c)) — so the oracle property is a sandwich, not equality:
        its decision is always valid, its reported time is the true f_m
        of that decision, and the exhaustive optimum lower-bounds it."""
        (pt, fc, bc, gt, dt), dt_bwd, asym = tup
        c = _mk(pt, fc, bc, gt, dt, dt_bwd=dt_bwd if asym else None)
        segs, t = ibatch_backward(c)
        validate_backward_segments(segs, c.num_layers)
        assert t == pytest.approx(backward_time(c, segs), rel=1e-9,
                                  abs=1e-9)
        _, opt = bruteforce_backward(c)
        assert t >= opt - 1e-9


class TestBandwidthMonotonicity:
    """More bandwidth can never hurt (ISSUE 4 satellite): comm costs scale
    as 1/bandwidth, so scaling pt/gt by s <= 1 must not increase any
    makespan — per fixed decision, at the optimum, and in the PS
    discrete-event simulator."""

    @settings(max_examples=100, deadline=None)
    @given(inst, st.floats(0.0, 1.0))
    def test_fixed_decision_times_monotone(self, tup, s):
        pt, fc, bc, gt, dt = tup
        c = _mk(pt, fc, bc, gt, dt)
        faster = c.scaled(comm=s)
        L = c.num_layers
        for segs in (((1, L),), lbl_forward(L)):
            assert forward_time(faster, segs) <= forward_time(c, segs) + 1e-9
        for segs in (((1, L),), lbl_backward(L)):
            assert backward_time(faster, segs) <= \
                backward_time(c, segs) + 1e-9

    @settings(max_examples=100, deadline=None)
    @given(inst, st.floats(0.0, 1.0))
    def test_optimum_monotone(self, tup, s):
        pt, fc, bc, gt, dt = tup
        c = _mk(pt, fc, bc, gt, dt)
        faster = c.scaled(comm=s)
        assert dp_forward(faster).time <= dp_forward(c).time + 1e-9
        assert dp_backward(faster).time <= dp_backward(c).time + 1e-9

    @settings(max_examples=50, deadline=None)
    @given(st.integers(2, 6).flatmap(lambda L: st.tuples(
        st.tuples(vec(L), vec(L), vec(L), vec(L), st.floats(0.0, 10.0)),
        st.tuples(vec(L), vec(L), vec(L), vec(L), st.floats(0.0, 10.0)),
        st.floats(0.0, 1.0))))
    def test_simulated_ps_makespan_monotone(self, tup):
        """The discrete-event PS makespan of a fixed shared decision is
        non-increasing when every link gets faster."""
        (t1, t2, s) = tup
        w1, w2 = _mk(*t1), _mk(*t2)       # same L: drawn from one flatmap
        L = w1.num_layers
        topo = TopologyCosts(workers=(w1, w2))
        d = (lbl_forward(L), lbl_backward(L))
        base = simulate_ps_iteration(topo, d).makespan
        fast = simulate_ps_iteration(topo.scaled(comm=s), d).makespan
        assert fast <= base + 1e-9
        # the simulator agrees with the closed-form straggler makespan
        assert base == pytest.approx(
            max(iteration_time(c, *d) for c in topo.workers))


class TestDecisionEncodings:
    @settings(max_examples=100, deadline=None)
    @given(st.integers(1, 12).flatmap(
        lambda L: st.lists(st.integers(0, 1), min_size=L - 1, max_size=L - 1)))
    def test_p_roundtrip(self, p):
        p = tuple(p)
        assert p_from_forward_segments(forward_segments_from_p(p)) == p

    @settings(max_examples=100, deadline=None)
    @given(st.integers(1, 12).flatmap(
        lambda L: st.lists(st.integers(0, 1), min_size=L - 1, max_size=L - 1)))
    def test_g_roundtrip(self, g):
        g = tuple(g)
        assert g_from_backward_segments(backward_segments_from_g(g)) == g
