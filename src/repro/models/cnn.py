"""The paper's four CNNs as layer-wise workload tables + a trainable CIFAR CNN.

DynaComm's own experiments run VGG-19, GoogLeNet, Inception-v4 and
ResNet-152 on ILSVRC12 (224x224).  For the §Faithful benchmarks we need
their *layer-wise heterogeneity* — per-layer parameter bytes and FLOPs —
which we derive analytically from the exact architectures.  Branching
modules (inception blocks, residual bottlenecks) collapse to one scheduling
layer, exactly as the paper prescribes ("parameters from different branches
with the same depth are considered as one layer"; paramless transforms fold
into their previous layer).

``SmallCNN`` is a real trainable JAX convnet (CIFAR-shaped) used for the
accuracy-untouched experiment (paper Fig. 10): we train it with and without
DynaComm bucketing and assert bit-identical losses.
"""

from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.profiler import LayerProfile

_DTYPE_BYTES = 4.0  # fp32 parameters, as in the paper's MXNet setup


def _conv(name, cin, cout, k, hw, stride=1, dtype_bytes=_DTYPE_BYTES):
    """Conv layer profile at input resolution hw (output hw/stride)."""
    out_hw = hw // stride
    params = k * k * cin * cout + cout
    flops = 2.0 * k * k * cin * cout * out_hw * out_hw
    return LayerProfile(name=name, param_bytes=params * dtype_bytes,
                        flops_fwd=flops), out_hw


def _fc(name, cin, cout, dtype_bytes=_DTYPE_BYTES):
    return LayerProfile(name=name, param_bytes=(cin * cout + cout) * dtype_bytes,
                        flops_fwd=2.0 * cin * cout)


def _scale(profiles: List[LayerProfile], batch: int) -> List[LayerProfile]:
    return [LayerProfile(name=p.name, param_bytes=p.param_bytes,
                         flops_fwd=p.flops_fwd * batch) for p in profiles]


# ---------------------------------------------------------------------------
# VGG-19: 16 conv + 3 fc
# ---------------------------------------------------------------------------


def vgg19_profiles(batch: int = 32) -> List[LayerProfile]:
    cfg = [(64, 2), (128, 2), (256, 4), (512, 4), (512, 4)]
    profs, cin, hw = [], 3, 224
    i = 0
    for cout, reps in cfg:
        for _ in range(reps):
            p, _ = _conv(f"conv{i}", cin, cout, 3, hw)
            profs.append(p)
            cin = cout
            i += 1
        hw //= 2  # maxpool folds into the previous conv (paper rule)
    profs.append(_fc("fc6", 512 * 7 * 7, 4096))
    profs.append(_fc("fc7", 4096, 4096))
    profs.append(_fc("fc8", 4096, 1000))
    return _scale(profs, batch)


# ---------------------------------------------------------------------------
# ResNet-152: conv1 + [3, 8, 36, 3] bottlenecks + fc
# ---------------------------------------------------------------------------


def _bottleneck(name, cin, mid, hw, stride):
    out_hw = hw // stride
    cout = mid * 4
    params = (1 * 1 * cin * mid) + (3 * 3 * mid * mid) + (1 * 1 * mid * cout)
    flops = 2.0 * (cin * mid * out_hw * out_hw
                   + 9 * mid * mid * out_hw * out_hw
                   + mid * cout * out_hw * out_hw)
    if stride != 1 or cin != cout:
        params += cin * cout
        flops += 2.0 * cin * cout * out_hw * out_hw
    return LayerProfile(name=name, param_bytes=params * _DTYPE_BYTES,
                        flops_fwd=flops), cout, out_hw


def resnet152_profiles(batch: int = 32) -> List[LayerProfile]:
    profs = []
    p, hw = _conv("conv1", 3, 64, 7, 224, stride=2)
    profs.append(p)
    hw //= 2  # maxpool
    cin = 64
    for stage, (mid, reps) in enumerate([(64, 3), (128, 8), (256, 36), (512, 3)]):
        for r in range(reps):
            stride = 2 if (r == 0 and stage > 0) else 1
            p, cin, hw = _bottleneck(f"s{stage}b{r}", cin, mid, hw, stride)
            profs.append(p)
    profs.append(_fc("fc", 2048, 1000))
    return _scale(profs, batch)


# ---------------------------------------------------------------------------
# GoogLeNet: stem + 9 inception modules + fc
# ---------------------------------------------------------------------------

_GOOGLE_INCEPTION = [
    # (1x1, 3x3red, 3x3, 5x5red, 5x5, poolproj, hw)
    (64, 96, 128, 16, 32, 32, 28),
    (128, 128, 192, 32, 96, 64, 28),
    (192, 96, 208, 16, 48, 64, 14),
    (160, 112, 224, 24, 64, 64, 14),
    (128, 128, 256, 24, 64, 64, 14),
    (112, 144, 288, 32, 64, 64, 14),
    (256, 160, 320, 32, 128, 128, 14),
    (256, 160, 320, 32, 128, 128, 7),
    (384, 192, 384, 48, 128, 128, 7),
]


def googlenet_profiles(batch: int = 32) -> List[LayerProfile]:
    profs = []
    p, hw = _conv("conv1", 3, 64, 7, 224, stride=2)
    profs.append(p)
    p, _ = _conv("conv2", 64, 192, 3, 56)
    profs.append(p)
    cin = 192
    for i, (c1, c3r, c3, c5r, c5, cp, hw) in enumerate(_GOOGLE_INCEPTION):
        params = (cin * c1 + cin * c3r + 9 * c3r * c3 + cin * c5r
                  + 25 * c5r * c5 + cin * cp)
        flops = 2.0 * hw * hw * (cin * c1 + cin * c3r + 9 * c3r * c3
                                 + cin * c5r + 25 * c5r * c5 + cin * cp)
        profs.append(LayerProfile(name=f"inception{i}",
                                  param_bytes=params * _DTYPE_BYTES,
                                  flops_fwd=flops))
        cin = c1 + c3 + c5 + cp
    profs.append(_fc("fc", 1024, 1000))
    return _scale(profs, batch)


# ---------------------------------------------------------------------------
# Inception-v4: stem convs + 4xA + 7xB + 3xC modules (+reductions) + fc
# ---------------------------------------------------------------------------


def _module(name, params, flops):
    return LayerProfile(name=name, param_bytes=params * _DTYPE_BYTES,
                        flops_fwd=flops)


def inceptionv4_profiles(batch: int = 32) -> List[LayerProfile]:
    profs = []
    # stem (3 convs + branch convs), folded per depth
    p, hw = _conv("stem0", 3, 32, 3, 299, stride=2)
    profs.append(p)
    p, _ = _conv("stem1", 32, 32, 3, hw)
    profs.append(p)
    p, _ = _conv("stem2", 32, 64, 3, hw)
    profs.append(p)
    profs.append(_module("stem_mix1", 64 * 96 * 9, 2.0 * 64 * 96 * 9 * 73 * 73))
    profs.append(_module("stem_mix2", 160 * 64 + 9 * 64 * 96 + 64 * 64 * 7 * 2,
                         2.0 * (160 * 64 + 9 * 64 * 96) * 71 * 71))
    # 4x Inception-A at 35x35, c=384
    for i in range(4):
        params = 384 * 96 * 2 + 384 * 64 * 2 + 9 * 64 * 96 + 9 * 96 * 96 * 2
        profs.append(_module(f"A{i}", params, 2.0 * params / _DTYPE_BYTES
                             * 0 + 2.0 * params * 35 * 35 / 4))
    profs.append(_module("redA", 9 * 384 * 384 + 384 * 192 + 9 * 192 * 224
                         + 9 * 224 * 256,
                         2.0 * (9 * 384 * 384 + 9 * 192 * 224) * 17 * 17))
    # 7x Inception-B at 17x17, c=1024
    for i in range(7):
        params = (1024 * 384 + 1024 * 192 + 1024 * 128 + 1024 * 192 * 2
                  + 7 * 192 * 224 * 2 + 7 * 224 * 256 * 2)
        profs.append(_module(f"B{i}", params, 2.0 * params * 17 * 17 / 4))
    profs.append(_module("redB", 1024 * 192 + 9 * 192 * 192 + 1024 * 256
                         + 7 * 256 * 320 + 9 * 320 * 320,
                         2.0 * (9 * 192 * 192 + 9 * 320 * 320) * 8 * 8))
    # 3x Inception-C at 8x8, c=1536
    for i in range(3):
        params = (1536 * 256 * 3 + 1536 * 384 * 2 + 3 * 384 * 256 * 4
                  + 3 * 384 * 512 + 3 * 512 * 256)
        profs.append(_module(f"C{i}", params, 2.0 * params * 8 * 8 / 4))
    profs.append(_fc("fc", 1536, 1000))
    return _scale(profs, batch)


PAPER_CNNS = {
    "vgg19": vgg19_profiles,
    "googlenet": googlenet_profiles,
    "inception-v4": inceptionv4_profiles,
    "resnet152": resnet152_profiles,
}


# ---------------------------------------------------------------------------
# SmallCNN — a real trainable convnet (CIFAR 32x32x3) with per-layer params
# ---------------------------------------------------------------------------


def small_cnn_init(key, num_classes: int = 10):
    ks = jax.random.split(key, 5)
    def conv_w(k, cin, cout, ksz=3):
        fan = ksz * ksz * cin
        return {
            "w": (jax.random.normal(k, (ksz, ksz, cin, cout))
                  / np.sqrt(fan)).astype(jnp.float32),
            "b": jnp.zeros((cout,), jnp.float32),
        }
    return {
        "layers": [
            conv_w(ks[0], 3, 32),
            conv_w(ks[1], 32, 64),
            conv_w(ks[2], 64, 128),
            {"w": (jax.random.normal(ks[3], (128 * 4 * 4, 256)) / 45.0
                   ).astype(jnp.float32), "b": jnp.zeros((256,), jnp.float32)},
            {"w": (jax.random.normal(ks[4], (256, num_classes)) / 16.0
                   ).astype(jnp.float32),
             "b": jnp.zeros((num_classes,), jnp.float32)},
        ]
    }


def _conv2d(x, w, b):
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + b


def _pool(x):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 2, 2, 1),
                                 (1, 2, 2, 1), "VALID")


def small_cnn_forward(params, images):
    """images: (B, 32, 32, 3) → logits (B, classes)."""
    x = images
    for i in range(3):
        p = params["layers"][i]
        x = _pool(jax.nn.relu(_conv2d(x, p["w"], p["b"])))
    x = x.reshape(x.shape[0], -1)
    p = params["layers"][3]
    x = jax.nn.relu(x @ p["w"] + p["b"])
    p = params["layers"][4]
    return x @ p["w"] + p["b"]


def small_cnn_loss(params, images, labels):
    logits = small_cnn_forward(params, images)
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))
