"""Hardware / network cost sources for the profiler.

Two regimes:

* ``EdgeNetworkModel`` — the paper's testbed: parameter servers on a cloud,
  workers at the edge, ~10 ms RTT, 1-10 Gbps.  Δt is the per-transmission
  setup + coordination overhead (the paper measures ≈14 ms era values for
  Δt + a first-layer transmission, Table I).
* ``TPUSystemModel`` — the adaptation target: TPU v5e pod.  "Transmission"
  becomes an all-gather (pull) or reduce-scatter (push) over the ``data``
  mesh axis; Δt becomes the fixed collective launch + ICI latency cost.

Both produce the same interface: per-layer pt/gt seconds from per-layer
byte counts, plus dt.

``NetworkSchedule`` adds the *time-varying* regime the dynamic trainer
re-schedules against: a piecewise-constant sequence of network models
indexed by epoch (e.g. the edge uplink degrading 10 Gbps → 1 Gbps at
epoch k), so the same profiling → DP → decision loop sees different pt/gt/Δt
as training progresses.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import numpy as np

# TPU v5e roofline constants (per chip) — also used by §Roofline.
TPU_PEAK_FLOPS_BF16 = 197e12          # FLOP/s
TPU_HBM_BW = 819e9                    # bytes/s
TPU_ICI_BW_PER_LINK = 50e9            # bytes/s per link (~ one direction)


@dataclasses.dataclass(frozen=True)
class EdgeNetworkModel:
    """Paper-faithful edge<->cloud network."""

    bandwidth_bps: float = 10e9       # bits per second (paper: 1/5/10 Gbps)
    rtt_s: float = 10.337e-3          # paper's measured average RTT
    setup_s: float = 3.5e-3           # socket/coordination setup per message

    @property
    def dt(self) -> float:
        # One RTT of coordination plus fixed setup per mini-procedure; with
        # the paper's constants this lands Δt ≈ 14 ms minus a first-layer
        # payload, matching Table I's (Δt + pt^1) ≈ 14 ms scale.
        return self.rtt_s + self.setup_s

    def transfer_time(self, nbytes: np.ndarray) -> np.ndarray:
        return np.asarray(nbytes, dtype=np.float64) * 8.0 / self.bandwidth_bps


@dataclasses.dataclass(frozen=True)
class TPUSystemModel:
    """TPU v5e pod: collectives over ICI on the ``data`` axis."""

    peak_flops: float = TPU_PEAK_FLOPS_BF16
    hbm_bw: float = TPU_HBM_BW
    ici_bw: float = TPU_ICI_BW_PER_LINK
    data_axis_size: int = 16
    collective_launch_s: float = 8e-6   # launch + DMA setup per collective
    ici_hop_latency_s: float = 1e-6     # per-hop latency, ring of data_axis_size
    mfu: float = 0.5                    # assumed model-flop utilization for fc/bc

    @property
    def dt(self) -> float:
        # A ring collective pays launch overhead plus (A-1) hop latencies
        # before the pipeline fills — the fixed, size-independent term.
        return self.collective_launch_s \
            + (self.data_axis_size - 1) * self.ici_hop_latency_s

    def transfer_time(self, nbytes: np.ndarray) -> np.ndarray:
        """Ring all-gather / reduce-scatter time for per-layer shard bytes.

        For a tensor of B bytes sharded A ways, each device moves
        B * (A-1)/A bytes through one link.
        """
        a = self.data_axis_size
        frac = (a - 1) / a
        return np.asarray(nbytes, dtype=np.float64) * frac / self.ici_bw

    def compute_time(self, flops: np.ndarray) -> np.ndarray:
        return np.asarray(flops, dtype=np.float64) / (self.peak_flops * self.mfu)


# ---------------------------------------------------------------------------
# Time-varying network conditions (the dynamic-rescheduling workload)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class NetworkSchedule:
    """Piecewise-constant time-varying network condition.

    ``knots`` is a sequence of ``(start_epoch, model)`` pairs with strictly
    increasing epochs starting at 0; ``model_at(e)`` returns the model of the
    last knot whose start epoch is <= ``e``.  Any object exposing the network
    interface (``dt`` + ``transfer_time``) can be a knot model.
    """

    knots: Tuple[Tuple[int, Any], ...]

    def __post_init__(self):
        knots = tuple((int(e), m) for e, m in self.knots)
        object.__setattr__(self, "knots", knots)
        if not knots:
            raise ValueError("NetworkSchedule needs at least one knot")
        epochs = [e for e, _ in knots]
        if epochs[0] != 0:
            raise ValueError(f"first knot must start at epoch 0, got "
                             f"{epochs[0]}")
        if any(b <= a for a, b in zip(epochs, epochs[1:])):
            raise ValueError(f"knot epochs must be strictly increasing, got "
                             f"{epochs}")

    def model_at(self, epoch: int) -> Any:
        if epoch < 0:
            raise ValueError(f"epoch must be >= 0, got {epoch}")
        active = self.knots[0][1]
        for start, model in self.knots:
            if start > epoch:
                break
            active = model
        return active

    @property
    def num_knots(self) -> int:
        return len(self.knots)


def as_schedule(net: Any) -> NetworkSchedule:
    """Wrap a static network model as a one-knot schedule (idempotent)."""
    if isinstance(net, NetworkSchedule):
        return net
    return NetworkSchedule(knots=((0, net),))


def bandwidth_shift(before_bps: float, after_bps: float, *, at_epoch: int,
                    rtt_s: float = EdgeNetworkModel.rtt_s,
                    setup_s: float = EdgeNetworkModel.setup_s
                    ) -> NetworkSchedule:
    """The drift demo scenario: an edge uplink whose bandwidth steps from
    ``before_bps`` to ``after_bps`` at epoch ``at_epoch`` (RTT unchanged)."""
    if at_epoch < 1:
        raise ValueError(f"at_epoch must be >= 1, got {at_epoch}")
    return NetworkSchedule(knots=(
        (0, EdgeNetworkModel(bandwidth_bps=before_bps, rtt_s=rtt_s,
                             setup_s=setup_s)),
        (at_epoch, EdgeNetworkModel(bandwidth_bps=after_bps, rtt_s=rtt_s,
                                    setup_s=setup_s)),
    ))
