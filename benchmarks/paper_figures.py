"""One function per paper table/figure (DynaComm, IEEE JSAC 2021).

Every function returns a list of row-dicts; ``benchmarks.run`` prints them
as CSV and EXPERIMENTS.md §Faithful quotes the numbers next to the paper's.
"""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from benchmarks.edge_setup import cnn_costs, edge_network
from repro.core import (STRATEGIES, backward_time, dp_backward, dp_forward,
                        evaluate, forward_time, ibatch_backward,
                        ibatch_forward, random_costs, schedule,
                        simulate_iteration)
from repro.core.baselines import (lbl_backward, lbl_forward,
                                  sequential_backward, sequential_forward)

MODELS = ("vgg19", "googlenet", "inception-v4", "resnet152")
LAYERWISE = ("lbl", "ibatch", "dynacomm")


def _phase_rows(batch: int, phase: str) -> List[Dict]:
    rows = []
    for model in MODELS:
        costs = cnn_costs(model, batch=batch)
        L = costs.num_layers
        seq = (forward_time(costs, sequential_forward(L)) if phase == "fwd"
               else backward_time(costs, sequential_backward(L)))
        for strat in ("sequential",) + LAYERWISE:
            f, b = schedule(costs, strat)
            t = forward_time(costs, f) if phase == "fwd" \
                else backward_time(costs, b)
            rows.append({
                "model": model, "strategy": strat, "phase": phase,
                "batch": batch, "time_s": round(t, 4),
                "normalized": round(t / seq, 4),
                "reduced_pct": round(100 * (1 - t / seq), 2),
            })
    return rows


def fig5_forward_bs32() -> List[Dict]:
    """Fig. 5: normalized forward execution time, batch 32."""
    return _phase_rows(32, "fwd")


def fig6_backward_bs32() -> List[Dict]:
    """Fig. 6: normalized backward execution time, batch 32."""
    return _phase_rows(32, "bwd")


def fig7_forward_bs16() -> List[Dict]:
    """Fig. 7: batch 16 forward."""
    return _phase_rows(16, "fwd")


def fig8_backward_bs16() -> List[Dict]:
    """Fig. 8: batch 16 backward."""
    return _phase_rows(16, "bwd")


def total_iteration_reduction() -> List[Dict]:
    """Paper text: total iteration-time reduction per model (bs 32 & 16)."""
    rows = []
    for batch in (32, 16):
        for model in MODELS:
            costs = cnn_costs(model, batch=batch)
            res = {s: evaluate(costs, schedule(costs, s))["total"]
                   for s in ("sequential", "lbl", "ibatch", "dynacomm")}
            rows.append({
                "model": model, "batch": batch,
                **{f"{s}_s": round(v, 3) for s, v in res.items()},
                "dynacomm_reduced_pct":
                    round(100 * (1 - res["dynacomm"] / res["sequential"]), 2),
            })
    return rows


def fig9a_batch_sensitivity() -> List[Dict]:
    """Fig. 9(a): iteration time reduced ratio vs batch size (ResNet-152)."""
    rows = []
    for batch in (8, 16, 24, 32, 48, 64):
        costs = cnn_costs("resnet152", batch=batch)
        seq = evaluate(costs, schedule(costs, "sequential"))["total"]
        for strat in LAYERWISE:
            t = evaluate(costs, schedule(costs, strat))["total"]
            rows.append({"batch": batch, "strategy": strat,
                         "reduced_pct": round(100 * (1 - t / seq), 2)})
    return rows


def fig9b_bandwidth_sensitivity() -> List[Dict]:
    """Fig. 9(b): reduction vs bandwidth (ResNet-152, batch 32)."""
    rows = []
    base = cnn_costs("resnet152", batch=32)   # 8 workers sharing the fabric
    for gbps in (1, 5, 10):
        costs = base.scaled(comm=10.0 / gbps)
        seq = evaluate(costs, schedule(costs, "sequential"))["total"]
        for strat in LAYERWISE:
            t = evaluate(costs, schedule(costs, strat))["total"]
            rows.append({"bandwidth_gbps": gbps, "strategy": strat,
                         "reduced_pct": round(100 * (1 - t / seq), 2)})
    return rows


def fig11_scalability() -> List[Dict]:
    """Fig. 11: speedup vs #workers (ResNet-152; server bandwidth shared)."""
    rows = []
    t1 = {}
    for workers in (1, 2, 4, 8):
        costs = cnn_costs("resnet152", batch=32, workers=workers)
        for strat in ("sequential",) + LAYERWISE:
            t = evaluate(costs, schedule(costs, strat))["total"]
            if workers == 1:
                t1[strat] = t
            rows.append({"workers": workers, "strategy": strat,
                         "iter_s": round(t, 3),
                         "speedup": round(workers * t1[strat] / t, 2)})
    return rows


def fig12_scheduling_complexity() -> List[Dict]:
    """Fig. 12: scheduling overhead vs number of layers (random profiles)."""
    rows = []
    for L in (20, 40, 80, 160, 320):
        costs = random_costs(L, seed=0, dt=5e-3)
        for name, fn in (
            ("dynacomm_fwd", lambda: dp_forward(costs)),
            ("dynacomm_bwd", lambda: dp_backward(costs)),
            ("ibatch_fwd", lambda: ibatch_forward(costs)),
            ("ibatch_bwd", lambda: ibatch_backward(costs)),
        ):
            t0 = time.perf_counter()
            fn()
            dt = time.perf_counter() - t0
            rows.append({"L": L, "scheduler": name,
                         "seconds": round(dt, 5)})
    return rows


def table1_scheduling_overhead() -> List[Dict]:
    """Table I: per-model scheduling cost vs the idle window (Δt + gt¹/pt¹)."""
    rows = []
    for model in MODELS:
        costs = cnn_costs(model, batch=32)
        samples_f, samples_b = [], []
        for _ in range(5):
            t0 = time.perf_counter()
            dp_forward(costs)
            samples_f.append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            dp_backward(costs)
            samples_b.append(time.perf_counter() - t0)
        window_f = costs.dt + float(costs.gt[0])    # Δt + gt_i^1
        window_b = costs.dt + float(costs.pt[0])    # Δt + pt_{i+1}^1
        rows.append({
            "model": model, "L": costs.num_layers,
            "dynacomm_fwd_ms": round(1e3 * float(np.mean(samples_f)), 3),
            "dynacomm_bwd_ms": round(1e3 * float(np.mean(samples_b)), 3),
            "idle_window_fwd_ms": round(1e3 * window_f, 2),
            "idle_window_bwd_ms": round(1e3 * window_b, 2),
            "hidden": bool(np.mean(samples_f) < window_f
                           and np.mean(samples_b) < window_b),
        })
    return rows


def breakdown_rows() -> List[Dict]:
    """Stacked-bar decomposition behind Figs. 5-8 (overlap accounting)."""
    rows = []
    for model in MODELS:
        costs = cnn_costs(model, batch=32)
        for strat in ("sequential", "lbl", "ibatch", "dynacomm"):
            f, b = schedule(costs, strat)
            tl = simulate_iteration(costs, f, b)
            for phase in ("forward", "backward"):
                br = tl.breakdown(phase)
                rows.append({
                    "model": model, "strategy": strat, "phase": phase,
                    "total_s": round(br.total, 4),
                    "comp_only_s": round(br.comp_only, 4),
                    "overlap_s": round(br.overlap, 4),
                    "comm_only_s": round(br.comm_only, 4),
                })
    return rows


def fig10_accuracy_untouched() -> List[Dict]:
    """Fig. 10: train the CIFAR CNN under different schedules — since the
    schedule only moves bytes, losses must be IDENTICAL (here: the same
    jitted math, decision recorded alongside; the multi-device bucketed
    trainer's bit-exactness is asserted in tests/test_dist.py)."""
    import jax
    import jax.numpy as jnp
    from repro.data.pipeline import SyntheticCIFAR
    from repro.models.cnn import small_cnn_init, small_cnn_loss
    from repro.optim import sgd

    rows = []
    curves = {}
    for strat in ("sequential", "dynacomm"):
        params = small_cnn_init(jax.random.PRNGKey(0))
        opt = sgd(0.05, momentum=0.9)
        state = opt.init(params)
        pipe = SyntheticCIFAR(batch_size=32, seed=0)

        @jax.jit
        def step(params, state, images, labels):
            loss, grads = jax.value_and_grad(small_cnn_loss)(
                params, images, labels)
            params, state = opt.update(grads, state, params)
            return params, state, loss

        losses = []
        for i in range(30):
            b = pipe.batch(i)
            params, state, loss = step(params, state, b["images"],
                                       b["labels"])
            losses.append(float(loss))
        curves[strat] = losses
        rows.append({"strategy": strat, "first_loss": round(losses[0], 6),
                     "last_loss": round(losses[-1], 6)})
    rows.append({"strategy": "identical",
                 "value": curves["sequential"] == curves["dynacomm"]})
    return rows


def table2_profiling_overhead() -> List[Dict]:
    """Table II: local training speed with the profiling switch on/off.

    Profiling = timing each layer's jitted fwd/bwd callables (the paper's
    mxnet.profiler analogue) once per epoch; overhead is the profiling
    wall time amortized over the epoch's iterations."""
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.core.profiler import time_callable
    from repro.data.pipeline import SyntheticText
    from repro.models import init_params, train_loss
    from repro.optim import adamw

    cfg = get_config("granite-3-2b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw(1e-3)
    state = opt.init(params)
    pipe = SyntheticText(cfg.vocab_size, 64, 8, seed=0)

    @jax.jit
    def step(params, state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: train_loss(cfg, p, batch))(params)
        params, state = opt.update(grads, state, params)
        return params, state, loss

    batch = pipe.batch(0)
    step(params, state, batch)  # compile
    t_iter = time_callable(lambda: step(params, state, batch), iters=5)

    # "profiler on": per-layer fwd timing pass (once per 195-iter epoch)
    from repro.models.blocks import apply_block
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 64, cfg.d_model))
    fns = [jax.jit(lambda p, h, k=kind: apply_block(p, h, cfg, k,
                                                    mode="train")[0])
           for kind in cfg.layer_kinds()]
    import time as _t
    t0 = _t.perf_counter()
    for fn, p in zip(fns, params["layers"]):
        time_callable(fn, p, x, iters=3, warmup=1)
    t_profile = _t.perf_counter() - t0
    per_iter_overhead = t_profile / 195.0
    return [{
        "iter_s_profiler_off": round(t_iter, 4),
        "iter_s_profiler_on": round(t_iter + per_iter_overhead, 4),
        "overhead_pct": round(100 * per_iter_overhead / t_iter, 3),
    }]


def dt_regime_ablation() -> List[Dict]:
    """Beyond-paper: how the optimal decomposition granularity tracks Δt.

    Sweeps Δt from ICI-scale (10 µs) to edge-scale (100 ms) on the
    ResNet-152 cost table: DynaComm's bucket count collapses from
    layer-by-layer toward sequential while staying optimal throughout —
    the single-algorithm-both-regimes property (paper Section VI, here
    quantified)."""
    rows = []
    base = cnn_costs("resnet152", batch=32)
    for regime, comm in (("compute-heavy", 1.0), ("comm-heavy", 4.0)):
        for dt in (1e-5, 1e-4, 1e-3, 1e-2, 1e-1):
            costs = base.scaled(comm=comm, dt=dt)
            for strat in ("lbl", "ibatch", "dynacomm"):
                f, b = schedule(costs, strat)
                t = evaluate(costs, (f, b))["total"]
                rows.append({"regime": regime, "dt_s": dt, "strategy": strat,
                             "fwd_buckets": len(f), "bwd_buckets": len(b),
                             "iter_s": round(t, 4)})
    return rows


def dynamic_rescheduling() -> List[Dict]:
    """Run-time loop payoff (the DynamicTrainer subsystem, cost-model view):
    the uplink drops by ``drift``×, and we compare keeping the stale
    10 Gbps-era decision against re-planning on the epoch boundary —
    exactly what ``repro.dist.dynamic.DynamicTrainer`` automates.  The gap
    is the price of *not* being dynamic (paper Section IV-C motivation)."""
    rows = []
    for model in MODELS:
        before = cnn_costs(model, batch=32)
        f0, b0 = schedule(before, "dynacomm")
        for drift in (4.0, 10.0):
            after = before.scaled(comm=drift)
            f1, b1 = schedule(after, "dynacomm")
            t_stale = evaluate(after, (f0, b0))["total"]
            t_replan = evaluate(after, (f1, b1))["total"]
            rows.append({
                "model": model, "bw_drop_x": drift,
                "buckets_before": f"{len(f0)}f/{len(b0)}b",
                "buckets_after": f"{len(f1)}f/{len(b1)}b",
                "replanned": (f0, b0) != (f1, b1),
                "iter_stale_s": round(t_stale, 4),
                "iter_replanned_s": round(t_replan, 4),
                "stale_penalty": round(t_stale / t_replan, 4),
            })
    return rows


ALL_BENCHES = {
    "fig5_forward_bs32": fig5_forward_bs32,
    "fig6_backward_bs32": fig6_backward_bs32,
    "fig7_forward_bs16": fig7_forward_bs16,
    "fig8_backward_bs16": fig8_backward_bs16,
    "total_iteration_reduction": total_iteration_reduction,
    "fig9a_batch_sensitivity": fig9a_batch_sensitivity,
    "fig9b_bandwidth_sensitivity": fig9b_bandwidth_sensitivity,
    "fig11_scalability": fig11_scalability,
    "fig12_scheduling_complexity": fig12_scheduling_complexity,
    "table1_scheduling_overhead": table1_scheduling_overhead,
    "table2_profiling_overhead": table2_profiling_overhead,
    "fig10_accuracy_untouched": fig10_accuracy_untouched,
    "breakdown": breakdown_rows,
    "dt_regime_ablation": dt_regime_ablation,
    "dynamic_rescheduling": dynamic_rescheduling,
}
