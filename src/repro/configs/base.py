"""Architecture configuration system.

One ``ArchConfig`` per assigned architecture (plus the paper's own CNNs,
which live in ``repro.models.cnn`` as layer-cost tables).  Configs are plain
frozen dataclasses — no framework magic — and every field needed by the
model builder, the sharding rules, the profiler and the dry-run lives here.
"""

from __future__ import annotations

import dataclasses
from typing import Literal, Optional, Tuple

Family = Literal["dense", "moe", "ssm", "vlm", "audio", "hybrid"]
LayerKind = Literal["global_attn", "local_attn", "mlstm", "slstm", "rglru"]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: Family
    citation: str

    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: Optional[int] = None          # default d_model // num_heads
    activation: str = "silu"                # silu | geglu | gelu
    gated_mlp: bool = True                  # SwiGLU/GeGLU-style 3-matrix MLP

    # attention pattern
    layer_pattern: Tuple[LayerKind, ...] = ()   # cycled over num_layers
    sliding_window: int = 0                  # for local_attn layers
    attn_logit_softcap: float = 0.0
    final_logit_softcap: float = 0.0
    causal: bool = True                      # False for encoder-only (hubert)

    # MoE
    num_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25

    # SSM / hybrid
    rglru_lru_width: Optional[int] = None    # default d_model
    mlstm_proj_factor: float = 2.0

    # modality frontend (stubbed): inputs are precomputed embeddings
    frontend: Literal["none", "vision", "audio"] = "none"
    num_vision_tokens: int = 0               # anyres patches prepended (vlm)

    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = True

    # capability flags for shape selection
    encoder_only: bool = False
    supports_long_context: bool = False      # sub-quadratic decode path exists

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if not self.layer_pattern:
            object.__setattr__(self, "layer_pattern", ("global_attn",))
        if self.num_heads % max(self.num_kv_heads, 1) != 0:
            raise ValueError(f"{self.name}: heads not divisible by kv heads")

    # ------------------------------------------------------------------
    def layer_kinds(self) -> Tuple[LayerKind, ...]:
        pat = self.layer_pattern
        return tuple(pat[i % len(pat)] for i in range(self.num_layers))

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    def reduced(self, *, num_layers: int = 2, d_model: int = 256,
                vocab: int = 512) -> "ArchConfig":
        """Smoke-test variant: same family/pattern, tiny dims (CPU-runnable)."""
        heads = min(self.num_heads, 4)
        kv = min(self.num_kv_heads, heads)
        while heads % kv:
            kv -= 1
        experts = min(self.num_experts, 4) if self.is_moe else 0
        top_k = min(self.top_k, experts) if experts else 0
        return dataclasses.replace(
            self,
            name=f"{self.name}-smoke",
            num_layers=num_layers,
            d_model=d_model,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=d_model // heads,
            d_ff=max(d_model * 2, 64) if self.d_ff else 0,
            vocab_size=vocab,
            num_experts=experts,
            top_k=top_k,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            rglru_lru_width=d_model if self.rglru_lru_width else None,
            num_vision_tokens=min(self.num_vision_tokens, 16),
        )


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    mode: Literal["train", "prefill", "decode"]


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ArchConfig, shape: InputShape) -> Tuple[bool, str]:
    """(runs?, reason-if-skipped) — the skip policy documented in DESIGN.md."""
    if shape.mode == "decode" and cfg.encoder_only:
        return False, "encoder-only architecture: no decode step exists"
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, ("pure full-attention architecture without a "
                       "sub-quadratic variant; long-context decode skipped")
    return True, ""
