"""gemma2-2b [arXiv:2408.00118] — local/global alternating, logit softcap."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-2b",
    family="dense",
    citation="arXiv:2408.00118",
    num_layers=26,
    d_model=2304,
    num_heads=8,
    num_kv_heads=4,
    d_ff=9216,
    vocab_size=256000,
    head_dim=256,
    activation="geglu",
    gated_mlp=True,
    layer_pattern=("local_attn", "global_attn"),
    sliding_window=4096,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    supports_long_context=True,   # sliding window; global-layer KV data-sharded
)
