"""Post-compile HLO analysis: collective traffic + roofline terms.

``collective_bytes`` parses the partitioned HLO text (``compiled.as_text()``)
and sums operand bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute — the quantity ``cost_analysis`` does not
report.  ``roofline`` combines it with HLO FLOPs/bytes into the three terms
of EXPERIMENTS.md §Roofline.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict

from repro.core.netmodel import (TPU_HBM_BW, TPU_ICI_BW_PER_LINK,
                                 TPU_PEAK_FLOPS_BF16)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([\d,]*)\]")
# "  %name = dtype[dims]{layout} opcode(operand, ...)" — tuple types allowed
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"
                     r"(\(.*?\)|[\w\[\]{},:#\d]+)\s+([\w\-]+)\((.*)$")
_OPERAND_RE = re.compile(r"%?([\w.\-]+)")


def cost_analysis_dict(compiled) -> Dict:
    """`Compiled.cost_analysis()` returns a dict or a one-element list of
    dicts depending on the jax version — normalize to a dict."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost or {}


def _shape_bytes(type_str: str) -> int:
    """Bytes of an HLO type string, e.g. 'bf16[8,128]{1,0}' or a tuple."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-op-kind operand bytes summed over the module (per device).

    Depending on the XLA version the printer writes operands either bare
    (``all-gather(%p0)``) or with their type inline
    (``all-gather(f32[1,16]{1,0} %bitcast)``).  Inline types are parsed
    directly; bare names are resolved against a name → output-type map
    built over all instruction definitions.
    """
    defs: Dict[str, str] = {}
    found = []
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, type_str, opcode, rest = m.groups()
        defs[name] = type_str
        base = opcode[:-6] if opcode.endswith("-start") else opcode
        if base in _COLLECTIVES:
            depth, end = 1, len(rest)
            for i, ch in enumerate(rest):  # operand list up to matching ')'
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        end = i
                        break
            found.append((base, rest[:end]))

    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for kind, operands in found:
        # inline style: every operand carries its own "dtype[dims]{...}"
        total = _shape_bytes(operands)
        if total == 0:
            # bare style: resolve "%name" operands against the def map
            # (names contain no commas, so the split is safe here)
            for op in operands.split(","):
                m = _OPERAND_RE.match(op.strip())
                if m and m.group(1) in defs:
                    total += _shape_bytes(defs[m.group(1)])
        out[kind] += total
        counts[kind] += 1
    out["_counts"] = counts
    return out


@dataclasses.dataclass(frozen=True)
class Roofline:
    flops: float                  # per-device HLO FLOPs
    hbm_bytes: float              # per-device HLO bytes accessed
    coll_bytes: float             # per-device collective operand bytes
    coll_detail: Dict[str, int]
    chips: int

    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=lambda kv: terms[kv])

    @property
    def bound_time(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)


def roofline(*, flops: float, hbm_bytes: float, coll: Dict[str, int],
             chips: int, peak_flops: float = TPU_PEAK_FLOPS_BF16,
             hbm_bw: float = TPU_HBM_BW,
             ici_bw: float = TPU_ICI_BW_PER_LINK) -> Roofline:
    """FLOPs/bytes from ``cost_analysis`` are PER-DEVICE for a partitioned
    module, so each term divides by a single chip's capability; ``chips``
    is retained for reporting."""
    coll_total = sum(v for k, v in coll.items() if not k.startswith("_"))
    return Roofline(
        flops=flops, hbm_bytes=hbm_bytes, coll_bytes=coll_total,
        coll_detail=coll, chips=chips,
        compute_s=flops / peak_flops,
        memory_s=hbm_bytes / hbm_bw,
        collective_s=coll_total / ici_bw,
    )
