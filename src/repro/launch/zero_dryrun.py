"""§Perf Pair C: the paper's technique itself at production scale.

Lowers the DynaComm bucketed ZeRO trainer on the 256-chip data mesh (the
PS-analogue: pure data parallelism) for each scheduling strategy, counts
the collectives, and evaluates the paper's objective f_m under the
TPU cost model — the paper-faithful comparison — plus a beyond-paper
steady-state pipelining bound (double-buffered cross-iteration overlap).

Usage: PYTHONPATH=src python -m repro.launch.zero_dryrun [--arch granite-3-2b]
"""

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import re

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import INPUT_SHAPES, get_config
from repro.core import (LayerCosts, TPUSystemModel, costs_from_profiles,
                        evaluate, plan_from_decision, schedule)
from repro.dist.zero import ZeroTrainer
from repro.launch.hlo_analysis import collective_bytes
from repro.launch.mesh import make_zero_mesh
from repro.models import num_sched_layers
from repro.models.profiles import layer_profiles
from repro.optim import adamw

S = jax.ShapeDtypeStruct


def tpu_costs(arch: str, shape_name: str, data_axis: int) -> LayerCosts:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    profs = layer_profiles(cfg, shape)
    # per-device compute: global layer FLOPs / data shards
    profs = [type(p)(name=p.name, param_bytes=p.param_bytes,
                     flops_fwd=p.flops_fwd / data_axis) for p in profs]
    net = TPUSystemModel(data_axis_size=data_axis)
    return costs_from_profiles(profs, net=net)


def state_structs(tr: ZeroTrainer):
    sh = tr._flat_sharding()
    flats = [S((spec.padded,), jnp.float32, sharding=sh) for spec in tr.specs]
    opt_state = jax.eval_shape(tr.optimizer.init, flats)
    opt_state = jax.tree_util.tree_map(
        lambda x: S(x.shape, x.dtype, sharding=sh) if x.ndim == 1
        else S(x.shape, x.dtype), opt_state)
    return {"flat_params": flats, "opt": opt_state,
            "step": S((), jnp.int32)}


def steady_state_bound(costs: LayerCosts, decision) -> float:
    """Beyond-paper: double-buffered cross-iteration pipelining.

    With weights double-buffered, iteration i+1's pulls overlap iteration
    i's backward; steady-state iteration time = max(link busy, compute
    busy) instead of the paper's serial fwd-phase + bwd-phase.
    """
    (fsegs, bsegs) = decision
    n = len(fsegs) + len(bsegs)
    link = n * costs.dt + float(np.sum(costs.pt) + np.sum(costs.gt))
    comp = float(np.sum(costs.fc) + np.sum(costs.bc))
    return max(link, comp)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--skip-lowering", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    mesh = make_zero_mesh()
    data_axis = mesh.shape["data"]
    cfg = get_config(args.arch)
    shape = INPUT_SHAPES[args.shape]
    costs = tpu_costs(args.arch, args.shape, data_axis)
    Ls = num_sched_layers(cfg)

    b_local = shape.global_batch
    batch_structs = {
        "tokens": S((b_local, shape.seq_len), jnp.int32,
                    sharding=NamedSharding(mesh, P("data", None))),
        "labels": S((b_local, shape.seq_len), jnp.int32,
                    sharding=NamedSharding(mesh, P("data", None))),
    }

    results = {"arch": args.arch, "shape": args.shape,
               "mesh": f"zero-{data_axis}", "dt_tpu": costs.dt,
               "strategies": {}}
    for strat in ("sequential", "lbl", "ibatch", "dynacomm"):
        decision = schedule(costs, strat)
        plan = plan_from_decision(*decision, Ls)
        times = evaluate(costs, decision)
        rec = {
            "fwd_buckets": len(plan.forward),
            "bwd_buckets": len(plan.backward),
            "fm_iteration_s": times["total"],
            "fm_forward_s": times["forward"],
            "fm_backward_s": times["backward"],
            "steady_state_s": steady_state_bound(costs, decision),
        }
        if not args.skip_lowering:
            tr = ZeroTrainer(cfg=cfg, mesh=mesh, plan=plan,
                             optimizer=adamw(1e-4))
            step = jax.jit(tr.build_train_step())
            lowered = step.lower(state_structs(tr), batch_structs)
            compiled = lowered.compile()
            hlo = compiled.as_text()
            coll = collective_bytes(hlo)
            mem = compiled.memory_analysis()
            rec.update({
                "hlo_all_gathers": coll["_counts"]["all-gather"],
                "hlo_reduce_scatters": coll["_counts"]["reduce-scatter"],
                "coll_bytes_per_device":
                    sum(v for k, v in coll.items() if not k.startswith("_")),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            })
        results["strategies"][strat] = rec
        print(strat, json.dumps(rec))

    seq = results["strategies"]["sequential"]["fm_iteration_s"]
    dyn = results["strategies"]["dynacomm"]["fm_iteration_s"]
    pipe = results["strategies"]["dynacomm"]["steady_state_s"]
    results["dynacomm_vs_sequential_pct"] = round(100 * (1 - dyn / seq), 2)
    results["pipelined_vs_dynacomm_pct"] = round(100 * (1 - pipe / dyn), 2)
    print("dynacomm reduces iteration by",
          results["dynacomm_vs_sequential_pct"], "% vs sequential;"
          " beyond-paper pipelining adds",
          results["pipelined_vs_dynacomm_pct"], "% on top")
    if args.out:
        with open(args.out, "a") as f:
            f.write(json.dumps(results) + "\n")


if __name__ == "__main__":
    main()
