"""Subprocess helper: multi-device checks for the dynamic-PS loop.

Run with 4 forged host devices.  Scenario: every worker's uplink degrades
10 Gbps → 1 Gbps at topology epoch 1 and recovers at epoch 2 (a
three-knot ``TopologySchedule``).  Prints one JSON line the parent
asserts on:

1. the consensus re-plan changes the BucketPlan when the uplinks degrade
   and returns to the original plan on recovery;
2. the compiled-step cache serves the revisited plan without re-tracing
   (traces == #distinct plans, cache_hits == #revisits);
3. per distinct plan, compiled-HLO all-gather / reduce-scatter counts
   equal the plan's segment counts (one pull + one push per segment);
4. the dynamic run's losses are bit-identical to statically running each
   epoch's consensus plan with ``PSTrainer.with_plan`` on the same
   batches;
5. every post-warmup boundary's DP fits the *topology's* Δt + gt¹ idle
   window (minimum over workers — Table I).
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import json

import jax
import numpy as np
from jax.sharding import Mesh

from repro.configs import get_config
from repro.configs.base import InputShape
from repro.core import consensus_decision, plan_from_decision
from repro.data.pipeline import SyntheticText
from repro.models import num_sched_layers
from repro.models.profiles import layer_profiles
from repro.optim import adamw
from repro.ps import (DynamicPSTrainer, PSTopology, PSTrainer,
                      TopologySchedule, uplink_degradation)

STEPS_PER_EPOCH, EPOCHS = 3, 3
B, T = 4, 32
FLOPS = 1e10


def main():
    cfg = get_config("granite-3-2b").reduced()
    mesh = Mesh(np.array(jax.devices()).reshape(4,), ("data",))
    pipe = SyntheticText(cfg.vocab_size, T, B, seed=0)
    base = PSTopology.uniform(2, 4, down_bps=10e9, up_bps=10e9, flops=FLOPS)
    degraded = uplink_degradation(base, factor=10,
                                  at_epoch=1).topology_at(1)
    sched = TopologySchedule(knots=((0, base), (1, degraded), (2, base)))
    shape = InputShape("dyn-ps", T, B, "train")
    num_steps = STEPS_PER_EPOCH * EPOCHS

    dyn = DynamicPSTrainer(cfg=cfg, mesh=mesh, optimizer=adamw(1e-3),
                           topology=sched,
                           steps_per_epoch=STEPS_PER_EPOCH,
                           input_shape=shape)
    state = dyn.init_state(jax.random.PRNGKey(0))
    state, losses_dyn = dyn.run(state, pipe.batch, num_steps)

    plans = []
    for plan in dyn.plans_seen:
        ag, rs = dyn.hlo_counts(plan)
        plans.append({"fwd": len(plan.forward), "bwd": len(plan.backward),
                      "ag": ag, "rs": rs})

    events = [{"step": e.step, "epoch": e.epoch,
               "fwd": len(e.plan.forward), "bwd": len(e.plan.backward),
               "changed": e.plan_changed, "retraced": e.retraced,
               "hidden": e.overhead_hidden,
               "sched_s": e.scheduling_seconds}
              for e in dyn.events]

    # ---- static reference: same plan sequence via PSTrainer.with_plan ----
    profs = layer_profiles(cfg, shape)
    Ls = num_sched_layers(cfg)

    def plan_for(epoch):
        costs = sched.topology_at(epoch).topology_costs(profs)
        decision, _ = consensus_decision(costs, "dynacomm")
        return plan_from_decision(*decision, Ls)

    ref = PSTrainer(cfg=cfg, mesh=mesh, plan=plan_for(0),
                    optimizer=adamw(1e-3), topology=base)
    state_s = ref.init_state(jax.random.PRNGKey(0))
    losses_static = []
    step_fns = {}
    for epoch in range(EPOCHS):
        plan = plan_for(epoch)
        if plan not in step_fns:
            step_fns[plan] = jax.jit(ref.with_plan(plan).build_train_step())
        for i in range(epoch * STEPS_PER_EPOCH,
                       (epoch + 1) * STEPS_PER_EPOCH):
            state_s, loss = step_fns[plan](state_s, pipe.batch(i))
            losses_static.append(float(loss))

    print(json.dumps({
        "losses_dyn": losses_dyn, "losses_static": losses_static,
        "traces": dyn.traces, "cache_hits": dyn.cache_hits,
        "plans": plans, "events": events,
    }))


if __name__ == "__main__":
    main()
