"""The ``Compressor`` carried on the push paths.

A compressor owns three things:

* **payload math** — ``roundtrip(flat)`` is compress-then-decompress of
  one FlatSpec buffer (what the server would reconstruct from the wire
  payload), and ``feedback_roundtrip(flat, residual)`` is the
  error-feedback variant: the quantization error of this push is kept in
  a per-(worker, layer) residual and re-injected into the next one, so
  the *accumulated* applied gradient is unbiased;
* **wire accounting** — ``wire_bytes(logical_bytes)`` maps fp32 payload
  bytes to what actually crosses the link (works elementwise on numpy
  arrays so the cost model can rescale whole ``gt`` vectors), plus a
  per-segment ``segment_overhead_bytes`` header cost;
* **backend routing** — with ``use_kernel=True`` the math runs through
  the fused Pallas kernels in ``repro.kernels.compress`` (the TPU path);
  otherwise through the pure-jnp oracles, which are bit-identical by
  construction (the tests assert it), so CPU runs stay fast without
  interpret-mode grid unrolling.

Schemes: ``none`` (identity), ``int8`` (per-TILE absmax quantization,
~3.97x on the wire), ``topk`` (magnitude top-k, index+value pairs,
``8 * ceil(fraction * n)`` wire bytes).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple, Union

import jax.numpy as jnp
import numpy as np

from repro.kernels.compress.ops import (TILE, aligned, densify,
                                        dequantize_unpack, quantize_pack,
                                        sparsify, topk_indices)
from repro.kernels.compress.ref import (densify_ref, dequantize_unpack_ref,
                                        quantize_pack_ref, sparsify_ref)

SCHEMES = ("none", "int8", "topk")

Bytes = Union[float, int, np.ndarray]


@dataclasses.dataclass(frozen=True)
class Compressor:
    """Identity compressor (scheme ``none``); also the subclass base."""

    error_feedback: bool = False
    use_kernel: bool = False

    scheme = "none"
    segment_overhead_bytes = 0.0

    # --- wire accounting -------------------------------------------------
    def wire_bytes(self, logical_bytes: Bytes) -> Bytes:
        """fp32 payload bytes → bytes actually crossing the link."""
        return np.asarray(logical_bytes, np.float64) * 1.0

    def ratio(self, logical_bytes: Bytes) -> float:
        """Compression ratio (>1 is smaller on the wire)."""
        wire = float(np.sum(self.wire_bytes(logical_bytes)))
        return float(np.sum(np.asarray(logical_bytes, np.float64))) / wire \
            if wire > 0 else 1.0

    # --- payload math ----------------------------------------------------
    def roundtrip(self, flat: jnp.ndarray) -> jnp.ndarray:
        """Compress-then-decompress one flat fp32 buffer."""
        return flat

    def feedback_roundtrip(self, flat: jnp.ndarray, residual: jnp.ndarray
                           ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Error-feedback step: returns (pushed payload, new residual)."""
        corrected = flat + residual
        compressed = self.roundtrip(corrected)
        return compressed, corrected - compressed


@dataclasses.dataclass(frozen=True)
class Int8Compressor(Compressor):
    """Per-TILE absmax int8: 1 byte/elem + one fp32 scale per TILE."""

    scheme = "int8"

    def wire_bytes(self, logical_bytes: Bytes) -> Bytes:
        n = np.asarray(logical_bytes, np.float64) / 4.0
        return n + 4.0 * np.ceil(n / TILE)

    def roundtrip(self, flat: jnp.ndarray) -> jnp.ndarray:
        n = int(flat.shape[0])
        npad = aligned(n)
        seg = jnp.pad(flat, (0, npad - n))[None, :]
        if self.use_kernel:
            payload, scales = quantize_pack(seg, (npad,))
            out = dequantize_unpack(payload, scales, (npad,), npad)
        else:
            payload, scales = quantize_pack_ref(seg, (npad,))
            out = dequantize_unpack_ref(payload, scales, (npad,), npad)
        return out[0, :n]


@dataclasses.dataclass(frozen=True)
class TopKCompressor(Compressor):
    """Magnitude top-k: ``ceil(fraction * n)`` (int32 index, fp32 value)
    pairs per buffer, plus a fixed per-segment length header."""

    fraction: float = 0.01

    scheme = "topk"
    segment_overhead_bytes = 8.0

    def __post_init__(self):
        if not 0.0 < self.fraction <= 1.0:
            raise ValueError(f"topk fraction must be in (0, 1], got "
                             f"{self.fraction}")

    def k_for(self, n: int) -> int:
        return max(1, int(math.ceil(self.fraction * n)))

    def wire_bytes(self, logical_bytes: Bytes) -> Bytes:
        n = np.asarray(logical_bytes, np.float64) / 4.0
        return 8.0 * np.maximum(1.0, np.ceil(self.fraction * n))

    def roundtrip(self, flat: jnp.ndarray) -> jnp.ndarray:
        n = int(flat.shape[0])
        idx = topk_indices(flat[None, :], (n,), self.k_for(n))
        if self.use_kernel:
            values = sparsify(flat[None, :], idx)
            out = densify(values, idx, n)
        else:
            values = sparsify_ref(flat[None, :], idx)
            out = densify_ref(values, idx, n)
        return out[0]


def make_compressor(scheme: str, *, topk_fraction: Optional[float] = None,
                    error_feedback: bool = True,
                    use_kernel: Optional[bool] = None) -> Compressor:
    """Build a compressor; ``use_kernel=None`` auto-routes by backend
    (fused Pallas kernels on TPU, bit-identical jnp math elsewhere)."""
    if scheme not in SCHEMES:
        raise ValueError(f"unknown compression scheme {scheme!r}; "
                         f"expected one of {SCHEMES}")
    if use_kernel is None:
        from repro._compat.pallas import default_interpret
        use_kernel = not default_interpret()
    if scheme == "none":
        if topk_fraction is not None:
            raise ValueError("topk_fraction only applies to scheme='topk'")
        return Compressor()
    if scheme == "int8":
        if topk_fraction is not None:
            raise ValueError("topk_fraction only applies to scheme='topk'")
        return Int8Compressor(error_feedback=error_feedback,
                              use_kernel=use_kernel)
    if topk_fraction is None:
        raise ValueError("scheme='topk' requires topk_fraction")
    return TopKCompressor(error_feedback=error_feedback,
                          use_kernel=use_kernel, fraction=topk_fraction)
