"""Per-architecture smoke tests + model-level consistency properties."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHITECTURES, get_config
from repro.data.pipeline import SyntheticText, batch_for
from repro.models import (decode_step, forward, init_caches, init_params,
                          num_sched_layers, param_count, sched_layer_bytes,
                          sched_layer_trees, train_loss)
from repro.models import scanned
from repro.optim import adamw

ALL_ARCHS = sorted(ARCHITECTURES)


def make_batch(cfg, B, T, key):
    if cfg.frontend == "audio":
        return {"frames": jax.random.normal(key, (B, T, cfg.d_model)) * 0.02,
                "labels": jnp.zeros((B, T), jnp.int32)}
    if cfg.frontend == "vision":
        nv = cfg.num_vision_tokens
        return {"tokens": jnp.ones((B, T - nv), jnp.int32),
                "vision_embeds": jax.random.normal(
                    key, (B, nv, cfg.d_model)) * 0.02,
                "labels": jnp.zeros((B, T - nv), jnp.int32)}
    toks = jax.random.randint(key, (B, T), 0, cfg.vocab_size)
    return {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}


# ---------------------------------------------------------------------------
# (f) per-arch smoke: reduced variant, one forward + one train step on CPU
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ALL_ARCHS)
class TestArchSmoke:
    def test_forward_shapes_and_finite(self, arch):
        cfg = get_config(arch).reduced()
        assert cfg.num_layers == 2 and cfg.d_model <= 512
        if cfg.is_moe:
            assert cfg.num_experts <= 4
        params = init_params(cfg, jax.random.PRNGKey(0))
        B, T = 2, 32
        batch = make_batch(cfg, B, T, jax.random.PRNGKey(1))
        logits, caches, aux = forward(cfg, params, batch, mode="train")
        exp_t = T if cfg.frontend != "vision" else T
        assert logits.shape == (B, exp_t, cfg.vocab_size)
        assert caches is None
        assert bool(jnp.all(jnp.isfinite(logits))), f"{arch}: NaN logits"

    def test_one_train_step(self, arch):
        cfg = get_config(arch).reduced()
        params = init_params(cfg, jax.random.PRNGKey(0))
        opt = adamw(1e-3)
        opt_state = opt.init(params)
        batch = make_batch(cfg, 2, 32, jax.random.PRNGKey(1))

        @jax.jit
        def step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(
                lambda p: train_loss(cfg, p, batch))(params)
            params, opt_state = opt.update(grads, opt_state, params)
            return params, opt_state, loss

        p1, o1, loss1 = step(params, opt_state, batch)
        _, _, loss2 = step(p1, o1, batch)
        assert np.isfinite(float(loss1)) and np.isfinite(float(loss2))
        assert float(loss2) < float(loss1), f"{arch}: loss did not descend"

    def test_decode_step_or_skip(self, arch):
        cfg = get_config(arch).reduced()
        if cfg.encoder_only:
            pytest.skip("encoder-only: no decode step (documented skip)")
        params = init_params(cfg, jax.random.PRNGKey(0))
        caches = init_caches(cfg, 2, 64)
        logits, new_caches = decode_step(cfg, params,
                                         jnp.ones((2, 1), jnp.int32), caches)
        assert logits.shape == (2, 1, cfg.vocab_size)
        assert bool(jnp.all(jnp.isfinite(logits)))
        assert len(new_caches) == cfg.num_layers


# ---------------------------------------------------------------------------
# consistency properties
# ---------------------------------------------------------------------------


DECODE_ARCHS = ["granite-3-2b", "gemma2-2b", "gemma3-4b", "xlstm-350m",
                "recurrentgemma-2b", "llava-next-34b"]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_prefill_decode_matches_full_forward(arch):
    cfg = get_config(arch).reduced()
    if cfg.is_moe:
        cfg = dataclasses.replace(cfg, capacity_factor=100.0)
    from repro.serve.decode import build_decode_step, prefill
    params = init_params(cfg, jax.random.PRNGKey(1))
    B, T, P = 2, 24, 12
    key = jax.random.PRNGKey(2)
    if cfg.frontend == "vision":
        pytest.skip("vision prefill exercised via batch path")
    toks = jax.random.randint(key, (B, T), 0, cfg.vocab_size)
    full_logits, _, _ = forward(cfg, params, {"tokens": toks}, mode="train")
    logits, caches = prefill(cfg, params, {"tokens": toks[:, :P]}, max_len=T)
    step = build_decode_step(cfg)
    errs = [float(jnp.max(jnp.abs(logits[:, -1] - full_logits[:, P - 1])))]
    for i in range(P, T):
        logits, caches = step(params, toks[:, i:i + 1], caches)
        errs.append(float(jnp.max(jnp.abs(logits[:, 0] - full_logits[:, i]))))
    assert max(errs) < 5e-4, f"{arch}: decode diverged from full forward"


@pytest.mark.parametrize("arch", ["granite-3-2b", "gemma2-2b", "xlstm-350m",
                                  "recurrentgemma-2b", "grok-1-314b"])
def test_scanned_matches_unrolled(arch):
    cfg = get_config(arch).reduced(num_layers=4)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, 2, 32, jax.random.PRNGKey(1))
    logits_u, _, aux_u = forward(cfg, params, batch, mode="train")
    sp = scanned.stack_layer_params(cfg, params)
    logits_s, _, aux_s = scanned.forward_scanned(cfg, sp, batch, mode="train",
                                                 remat=False)
    np.testing.assert_allclose(np.asarray(logits_u), np.asarray(logits_s),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(float(aux_u), float(aux_s), rtol=1e-5)


def test_chunked_attention_matches_full():
    from repro.models.attention import _mask_bias, _sdpa, _sdpa_chunked
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    B, T, H, HKV, hd = 2, 256, 4, 2, 32
    q = jax.random.normal(ks[0], (B, T, H, hd))
    k = jax.random.normal(ks[1], (B, T, HKV, hd))
    v = jax.random.normal(ks[2], (B, T, HKV, hd))
    pos = jnp.arange(T)
    for causal, window, cap in [(True, 0, 0.0), (True, 48, 0.0),
                                (True, 0, 30.0), (False, 0, 0.0)]:
        bias = _mask_bias(pos, pos, causal=causal, window=window,
                          dtype=jnp.float32)
        full = _sdpa(q, k, v, bias, 2, cap)
        chk = _sdpa_chunked(q, k, v, n_rep=2, cap=cap, causal=causal,
                            window=window, chunk=64)
        np.testing.assert_allclose(np.asarray(full), np.asarray(chk),
                                   atol=2e-5)


def test_mlstm_chunkwise_matches_parallel():
    from repro.models.ssm import _mlstm_chunkwise, _mlstm_parallel
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    B, H, T, hd = 2, 2, 128, 16
    q, k, v = (jax.random.normal(ks[i], (B, H, T, hd)) for i in range(3))
    ig = jax.random.normal(ks[3], (B, H, T))
    fg = jax.random.normal(ks[4], (B, H, T)) + 2.0
    h_par = _mlstm_parallel(q, k, v, ig, fg)
    h_chk, _ = _mlstm_chunkwise(q, k, v, ig, fg, chunk=32)
    np.testing.assert_allclose(np.asarray(h_par), np.asarray(h_chk),
                               atol=5e-4)


def test_cross_entropy_matches_naive():
    from repro.models.model import cross_entropy
    key = jax.random.PRNGKey(0)
    logits = jax.random.normal(key, (4, 8, 33))
    labels = jax.random.randint(jax.random.PRNGKey(1), (4, 8), 0, 33)
    labels = labels.at[0, 0].set(-1)   # ignored position
    got = float(cross_entropy(logits, labels))
    logp = jax.nn.log_softmax(logits, axis=-1)
    mask = np.asarray(labels) >= 0
    naive = -np.asarray(logp)[np.arange(4)[:, None], np.arange(8)[None, :],
                              np.maximum(np.asarray(labels), 0)]
    want = float((naive * mask).sum() / mask.sum())
    assert got == pytest.approx(want, rel=1e-6)


# ---------------------------------------------------------------------------
# config exactness (the assigned table) + profiles
# ---------------------------------------------------------------------------


EXPECT = {
    # name: (layers, d_model, heads, kv, d_ff, vocab)
    "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
    "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
    "llava-next-34b": (60, 7168, 56, 8, 20480, 64000),
    "gemma3-4b": (34, 2560, 8, 4, 10240, 262144),
    "hubert-xlarge": (48, 1280, 16, 16, 5120, 504),
    "gemma-7b": (28, 3072, 16, 16, 24576, 256000),
    "granite-3-2b": (40, 2048, 32, 8, 8192, 49155),
    "grok-1-314b": (64, 6144, 48, 8, 32768, 131072),
    "gemma2-2b": (26, 2304, 8, 4, 9216, 256000),
    "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
}


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_config_matches_assignment(arch):
    cfg = get_config(arch)
    want = EXPECT[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == want
    assert cfg.citation


def test_moe_details():
    g = get_config("granite-moe-1b-a400m")
    assert (g.num_experts, g.top_k) == (32, 8)
    k = get_config("grok-1-314b")
    assert (k.num_experts, k.top_k) == (8, 2)


def test_param_counts_near_model_cards():
    # billions, generous tolerance (embeddings/tying conventions vary)
    targets = {"grok-1-314b": 314, "llava-next-34b": 34, "gemma-7b": 8.5,
               "gemma3-4b": 4, "gemma2-2b": 2.6, "recurrentgemma-2b": 2.7,
               "granite-3-2b": 2.5, "granite-moe-1b-a400m": 1.3,
               "hubert-xlarge": 1.0, "xlstm-350m": 0.45}
    for arch, tgt in targets.items():
        n = param_count(get_config(arch)) / 1e9
        assert abs(n - tgt) / tgt < 0.25, f"{arch}: {n:.2f}B vs {tgt}B"


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_sched_layer_profiles(arch):
    from repro.configs.base import INPUT_SHAPES
    from repro.models.profiles import layer_profiles
    cfg = get_config(arch)
    profs = layer_profiles(cfg, INPUT_SHAPES["train_4k"])
    assert len(profs) == num_sched_layers(cfg)
    assert all(p.flops_fwd >= 0 and p.param_bytes >= 0 for p in profs)
    assert sum(p.flops_fwd for p in profs) > 0
    bytes_ = sched_layer_bytes(cfg)
    assert sum(bytes_) == param_count(cfg) * 4


def test_data_pipeline_deterministic():
    p = SyntheticText(vocab_size=128, seq_len=16, batch_size=4, seed=7)
    b1, b2 = p.batch(3), p.batch(3)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = p.batch(4)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))
