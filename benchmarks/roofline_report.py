"""§Roofline table builder: reads the dry-run JSONL records and emits the
per-(arch × shape × mesh) roofline rows (terms in seconds, dominant
bottleneck, MODEL_FLOPS/HLO ratio, improvement note)."""

from __future__ import annotations

import json
import os
from typing import Dict, List

EXP_DIR = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "experiments")

_NOTES = {
    ("compute",): "raise arithmetic intensity (larger microbatch / fuse)",
    ("memory",): "cut HBM traffic: better remat policy, bf16 residuals, "
                 "fused attention",
    ("collective",): "coarser/bucketed collectives, overlap with compute, "
                     "or shed FSDP gathers (replicate params for decode)",
}


def load_records(path: str) -> List[Dict]:
    recs = []
    if not os.path.exists(path):
        return recs
    with open(path) as f:
        for line in f:
            recs.append(json.loads(line))
    return recs


def analytic_flops_per_device(arch: str, shape_name: str, chips: int) -> float:
    """Whole-step FLOPs from the per-layer analytic model.

    XLA's CPU ``cost_analysis`` does not multiply loop (scan) bodies by
    their trip count, so HLO FLOPs undercount the layer stack; the analytic
    model is exact for the matmul-dominated layers (validated against an
    unrolled lowering in tests).  train ≈ 4× forward (bwd 2×, remat refwd 1×).
    """
    from repro.configs import INPUT_SHAPES, get_config
    from repro.models.profiles import layer_profiles
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    fwd = sum(p.flops_fwd for p in layer_profiles(cfg, shape))
    mult = 4.0 if shape.mode == "train" else 1.0
    return fwd * mult / chips


def roofline_rows(jsonl: str = "dryrun_single_pod.jsonl") -> List[Dict]:
    from repro.core.netmodel import TPU_PEAK_FLOPS_BF16
    rows = []
    for r in load_records(os.path.join(EXP_DIR, jsonl)):
        if r["status"] == "skip":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "mesh": r["mesh"], "status": "skip",
                         "note": r["reason"][:60]})
            continue
        if r["status"] != "ok":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "mesh": r["mesh"], "status": "error",
                         "note": r["error"][:60]})
            continue
        rl = r["roofline"]
        analytic = analytic_flops_per_device(r["arch"], r["shape"],
                                             r["chips"])
        compute_s = max(rl["compute_s"], analytic / TPU_PEAK_FLOPS_BF16)
        terms = {"compute": compute_s, "memory": rl["memory_s"],
                 "collective": rl["collective_s"]}
        dominant = max(terms, key=lambda k: terms[k])
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "status": "ok",
            "compute_s": f"{compute_s:.3e}",
            "memory_s": f"{rl['memory_s']:.3e}",
            "collective_s": f"{rl['collective_s']:.3e}",
            "dominant": dominant,
            "bound_s": f"{max(terms.values()):.3e}",
            "temp_GB": round(r["memory"]["temp_bytes"] / 1e9, 1),
            "model_flops_frac": round(
                (r["model_flops_per_device"] / analytic)
                if analytic else 0.0, 3),
            "note": _NOTES[(dominant,)],
        })
    return rows
