"""Serving: prefill + KV-cache decode with batched requests.

``prefill`` runs the full-sequence forward and returns per-layer caches;
``build_decode_step`` yields the jit-able one-token ``serve_step`` that the
decode dry-run shapes (decode_32k / long_500k) lower on the production mesh.
"""

from __future__ import annotations

from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import model as model_lib


def pad_caches(cfg: ArchConfig, caches: List[Any], max_len: int) -> List[Any]:
    """Grow global-attention KV caches to max_len (decode writes past t)."""
    from repro.models.attention import KVCache
    out = []
    for kind, c in zip(cfg.layer_kinds(), caches):
        if kind == "global_attn" and isinstance(c, KVCache) \
                and c.k.shape[1] < max_len:
            pad = max_len - c.k.shape[1]
            c = KVCache(
                k=jnp.pad(c.k, ((0, 0), (0, pad), (0, 0), (0, 0))),
                v=jnp.pad(c.v, ((0, 0), (0, pad), (0, 0), (0, 0))),
                pos=c.pos)
        out.append(c)
    return out


def prefill(cfg: ArchConfig, params, batch: Dict[str, jnp.ndarray], *,
            max_len: int | None = None) -> Tuple[jnp.ndarray, List[Any]]:
    """Returns (last-position logits, caches sized for max_len decode)."""
    logits, caches, _ = model_lib.forward(cfg, params, batch, mode="prefill",
                                          last_only=True)
    if max_len is not None:
        caches = pad_caches(cfg, caches, max_len)
    return logits, caches


def build_decode_step(cfg: ArchConfig):
    def serve_step(params, token, caches):
        return model_lib.decode_step(cfg, params, token, caches)
    return serve_step


def batched_generate(cfg: ArchConfig, params, prompts: jnp.ndarray, *,
                     max_new_tokens: int, greedy: bool = True,
                     key=None) -> jnp.ndarray:
    """Generate continuations for a batch of same-length prompts."""
    b, t = prompts.shape
    logits, caches = prefill(cfg, params, {"tokens": prompts},
                             max_len=t + max_new_tokens)
    step = jax.jit(build_decode_step(cfg))

    tokens = []
    cur = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    # prefill only cached t tokens; decode continues from position t
    for i in range(max_new_tokens):
        tokens.append(cur)
        logits, caches = step(params, cur, caches)
        if greedy or key is None:
            cur = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        else:
            key, sub = jax.random.split(key)
            cur = jax.random.categorical(sub, logits[:, -1])[:, None] \
                .astype(jnp.int32)
    return jnp.concatenate(tokens, axis=1)
