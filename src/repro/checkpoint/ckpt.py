"""Flat-path npz checkpointing for arbitrary pytrees (params + opt state).

No external deps: pytree leaves are stored under their joined key path;
restore rebuilds into a caller-provided template tree (shape/dtype checked),
so sharded restores can re-place leaves per device after load.
"""

from __future__ import annotations

import os
from typing import Any

import jax
import numpy as np


def _flatten_with_paths(tree: Any):
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = np.asarray(leaf)
    return out


def save_checkpoint(path: str, tree: Any, *, step: int | None = None) -> None:
    flat = _flatten_with_paths(tree)
    if step is not None:
        flat["__step__"] = np.asarray(step)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    tmp = path + ".tmp"
    np.savez(tmp, **flat)
    os.replace(tmp + ".npz" if not tmp.endswith(".npz") else tmp, path)


def load_checkpoint(path: str, template: Any):
    """Returns (tree_like_template, step_or_None).

    Text leaves in the template (numpy unicode/bytes kinds) are restored
    as stored: their dtype width varies with content (JSON-encoded
    metadata, plan descriptions), so no shape/dtype check applies.
    """
    data = np.load(path)
    flat_t = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for p, leaf in flat_t[0]:
        key = "/".join(str(getattr(q, "key", getattr(q, "idx", q)))
                       for q in p)
        arr = data[key]
        if np.asarray(leaf).dtype.kind in ("U", "S"):
            leaves.append(arr)
            continue
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {leaf.shape}")
        leaves.append(arr.astype(leaf.dtype))
    step = int(data["__step__"]) if "__step__" in data else None
    return jax.tree_util.tree_unflatten(flat_t[1], leaves), step
