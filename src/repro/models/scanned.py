"""Scan-over-layers forward (compile-time-friendly production path).

The canonical model stores per-layer parameter trees (the view DynaComm
schedules over).  For lowering/compiling the full-scale configs, XLA compile
time is dominated by the unrolled layer stack, so this module provides the
standard MaxText-style alternative: parameters stacked along a leading
group axis and a ``lax.scan`` over pattern-period groups.  Math is
identical to ``model.forward`` (asserted in tests).

Layout: the layer pattern (period p) tiles the stack; full periods are
scanned, the remainder (num_layers mod p) is unrolled.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import blocks as blocks_lib
from repro.models import model as model_lib


def group_count(cfg: ArchConfig) -> Tuple[int, int]:
    p = len(cfg.layer_pattern)
    return cfg.num_layers // p, cfg.num_layers % p


def stack_layer_params(cfg: ArchConfig, params: Dict[str, Any]) -> Dict[str, Any]:
    """Per-layer list → {embed, stack:[p stacked trees], remainder, final}."""
    p = len(cfg.layer_pattern)
    n_groups, rem = group_count(cfg)
    stack = []
    if n_groups > 0:
        for j in range(p):
            trees = [params["layers"][i * p + j] for i in range(n_groups)]
            stack.append(jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *trees))
    remainder = params["layers"][n_groups * p:]
    return {"embed": params["embed"], "stack": stack,
            "remainder": remainder, "final": params["final"]}


def unstack_layer_params(cfg: ArchConfig, sp: Dict[str, Any]) -> Dict[str, Any]:
    p = len(cfg.layer_pattern)
    n_groups, _ = group_count(cfg)
    layers: List[Any] = []
    for i in range(n_groups):
        for j in range(p):
            layers.append(jax.tree_util.tree_map(lambda x: x[i], sp["stack"][j]))
    layers.extend(sp["remainder"])
    return {"embed": sp["embed"], "layers": layers, "final": sp["final"]}


def init_stacked(cfg: ArchConfig, key, dtype=jnp.float32) -> Dict[str, Any]:
    return stack_layer_params(cfg, model_lib.init_params(cfg, key, dtype))


def forward_scanned(cfg: ArchConfig, sp: Dict[str, Any],
                    batch: Dict[str, jnp.ndarray], *, mode: str = "train",
                    remat: bool = True, last_only: bool = False,
                    act_sharding=None, logits_sharding=None,
                    barrier: bool = False, remat_sqrt: int = 0):
    """Returns (logits, caches_or_None, aux).  train/prefill only.

    ``act_sharding``: optional NamedSharding pinned onto the (B, T, d)
    activations at every block boundary — without it GSPMD sometimes
    drifts to replicated-batch layouts inside the stack.
    """
    assert mode in ("train", "prefill")
    pattern = cfg.layer_pattern
    n_groups, rem = group_count(cfg)

    def pin(x):
        if act_sharding is not None:
            return jax.lax.with_sharding_constraint(x, act_sharding)
        return x

    x = pin(model_lib._embed_inputs(cfg, {"embed": sp["embed"]}, batch))

    def group_body(x, group_trees):
        if barrier:
            # keep the remat-saved carry in bf16: without this XLA hoists the
            # first consumer's f32 convert over the whole (groups, B, T, d)
            # residual stack, doubling its bytes (§Perf, grok iteration 2)
            x = jax.lax.optimization_barrier(x)
        aux = jnp.zeros((), jnp.float32)
        caches = []
        for j, kind in enumerate(pattern):
            x, c, a = blocks_lib.apply_block(group_trees[j], x, cfg, kind,
                                             mode=mode, cache=None)
            x = pin(x)
            aux = aux + a
            caches.append(c)
        return x, (aux, tuple(caches) if mode == "prefill" else None)

    body = jax.checkpoint(group_body) if remat else group_body

    if n_groups > 0 and remat_sqrt > 1 and n_groups % remat_sqrt == 0 \
            and mode == "train":
        # two-level (√-remat) scan: the outer scan checkpoints only
        # n_groups/remat_sqrt carries; each outer step re-runs an inner scan
        # of remat_sqrt groups during backward.  Cuts the dominant
        # (groups, B, T, d) residual stack by the factor at ~1 extra forward
        # of recompute (§Perf, grok iteration 4).
        g1 = n_groups // remat_sqrt
        stack2 = tuple(
            jax.tree_util.tree_map(
                lambda t: t.reshape((g1, remat_sqrt) + t.shape[1:]), tree)
            for tree in sp["stack"])

        def outer_body(x, outer_trees):
            def inner(carry, gp):
                y, (a, _) = body(carry, gp)
                return y, a
            x, auxs = jax.lax.scan(inner, x, outer_trees)
            return x, jnp.sum(auxs)

        x, auxs = jax.lax.scan(jax.checkpoint(outer_body), x, stack2)
        aux = jnp.sum(auxs)
        caches_scanned = None
    elif n_groups > 0:
        x, (auxs, caches_scanned) = jax.lax.scan(
            lambda carry, gp: body(carry, gp), x, tuple(sp["stack"]))
        aux = jnp.sum(auxs)
    else:
        caches_scanned = None
        aux = jnp.zeros((), jnp.float32)

    rem_caches = []
    for r, tree in enumerate(sp["remainder"]):
        kind = pattern[r % len(pattern)]
        x, c, a = blocks_lib.apply_block(tree, x, cfg, kind, mode=mode,
                                         cache=None)
        x = pin(x)
        aux = aux + a
        rem_caches.append(c)

    if last_only:
        x = x[:, -1:]
    logits = model_lib._head(cfg, {"embed": sp["embed"], "final": sp["final"]}, x)
    if logits_sharding is not None:
        logits = jax.lax.with_sharding_constraint(logits, logits_sharding)
    caches = None
    if mode == "prefill":
        caches = {"scanned": caches_scanned, "remainder": rem_caches}
    return logits, caches, aux


def train_loss_scanned(cfg: ArchConfig, sp, batch, *, aux_weight: float = 0.01,
                       remat: bool = True, act_sharding=None,
                       logits_sharding=None, barrier: bool = False,
                       remat_sqrt: int = 0) -> jnp.ndarray:
    logits, _, aux = forward_scanned(cfg, sp, batch, mode="train", remat=remat,
                                     act_sharding=act_sharding,
                                     logits_sharding=logits_sharding,
                                     barrier=barrier, remat_sqrt=remat_sqrt)
    labels = batch["labels"]
    if cfg.frontend == "vision":
        nv = logits.shape[1] - labels.shape[1]
        pad = jnp.full(labels.shape[:1] + (nv,), -1, labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
    return model_lib.cross_entropy(logits, labels) + aux_weight * aux
