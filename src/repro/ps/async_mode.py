"""Bounded-staleness asynchronous PS execution.

Synchronous mode (``repro.ps.worker.PSTrainer``) pays the straggler at
every barrier; this module removes the barrier: each worker pulls a
parameter snapshot, computes gradients *against that version*, and pushes
— the server accepts the push only if the worker is at most ``k``
versions behind the head (Stale Synchronous Parallel, k=0 degenerating to
fully-serialized sequential SGD).  A rejected worker re-pulls the head
version and recomputes, which is exactly the liveness rule that bounds
every *applied* gradient's staleness by ``k``.

Execution is a deterministic discrete-event simulation driven by the
topology's per-worker costs: each worker's pull → compute → push latency
comes from its own ``LayerCosts`` under the shared ``BucketPlan`` (via
``core.simulator``), the event queue orders commits by simulated time
(ties by worker id), and gradient math runs for real through one jitted
``value_and_grad`` shared by all workers — so runs are reproducible
bit-for-bit and the staleness trace is machine-checkable, while losses
come from actually training the model (the smoke-CNN convergence test).

The trainer is generic over "a model whose parameters are a list of
per-layer pytrees + a loss function": the smoke CNN
(``repro.models.cnn``) and the text archs (``sched_layer_trees`` +
``train_loss``) both fit.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax

from repro.core.buckets import BucketPlan, decision_from_plan
from repro.core.costmodel import TopologyCosts, iteration_time
from repro.dist.collectives import (FlatSpec, flatten_tree, make_flat_spec,
                                    unflatten_tree)
from repro.optim import Optimizer
from repro.ps.server import PSServer, PushResult, StaleVersion
from repro.ps.topology import PSTopology


@dataclasses.dataclass(frozen=True)
class AsyncPushEvent:
    """One committed (accepted or rejected) push, in commit order."""

    worker: int
    sim_time: float           # simulated seconds at commit
    version: int              # version the gradients were computed at
    result: PushResult
    loss: float
    retries: int              # stale rejections before this commit


@dataclasses.dataclass
class AsyncRunLog:
    events: List[AsyncPushEvent] = dataclasses.field(default_factory=list)

    @property
    def accepted(self) -> List[AsyncPushEvent]:
        return [e for e in self.events if e.result.accepted]

    @property
    def losses(self) -> List[float]:
        return [e.loss for e in self.accepted]

    @property
    def max_staleness(self) -> int:
        return max((e.result.staleness for e in self.accepted), default=0)

    @property
    def num_rejected(self) -> int:
        return sum(1 for e in self.events if not e.result.accepted)

    @property
    def makespan(self) -> float:
        return max((e.sim_time for e in self.events), default=0.0)


class AsyncPSTrainer:
    """Event-driven bounded-staleness trainer over a PS topology.

    Parameters
    ----------
    init_layers:
        per-layer parameter pytrees (the model's sched-layer view).
    loss_fn:
        ``loss_fn(layers, batch) -> scalar`` over the *assembled* layer
        list; differentiated once with ``jax.value_and_grad`` and shared
        by every worker.
    plan:
        the shared ``BucketPlan`` — each forward bucket is one pull
        message, each backward bucket one push message.
    staleness:
        the bound ``k``: a push computed at version ``v`` commits only if
        ``head − v ≤ k``.
    costs:
        optional per-worker ``TopologyCosts`` driving the simulated
        clock; without it every worker's iteration costs one unit, which
        keeps the event order deterministic but uninformative.
    """

    def __init__(self, *, init_layers: Sequence[Any],
                 loss_fn: Callable[[List[Any], Dict[str, Any]], Any],
                 optimizer: Optimizer, topology: PSTopology,
                 plan: BucketPlan, staleness: int = 1,
                 costs: Optional[TopologyCosts] = None):
        init_layers = list(init_layers)
        if not init_layers:
            raise ValueError("need at least one layer tree")
        self.topology = topology
        self.plan = plan
        self.staleness = staleness
        self.specs: Tuple[FlatSpec, ...] = tuple(
            make_flat_spec(t, 1) for t in init_layers)
        L = len(self.specs)
        for direction in ("forward", "backward"):
            covered = sorted(l for b in getattr(plan, direction) for l in b)
            if covered != list(range(L)):
                raise ValueError(f"plan's {direction} buckets cover layers "
                                 f"{covered}, model has 0..{L - 1}")
        flats = [flatten_tree(t, s) for t, s in zip(init_layers, self.specs)]
        self.server = PSServer(self.specs, topology, optimizer, flats,
                               staleness_bound=staleness)
        self._grad_fn = jax.jit(jax.value_and_grad(loss_fn))
        if costs is not None and costs.num_workers != topology.num_workers:
            raise ValueError(f"costs for {costs.num_workers} workers, "
                             f"topology has {topology.num_workers}")
        self._costs = costs
        self._durations = self._iteration_durations()

    def _iteration_durations(self) -> Tuple[float, ...]:
        if self._costs is None:
            # compute-bound default: duration ∝ 1 / worker compute rate,
            # normalized so the fastest worker's iteration is one unit
            flops = self.topology.worker_flops
            fastest = max(flops)
            return tuple(fastest / f for f in flops)
        decision = decision_from_plan(self.plan)
        return tuple(iteration_time(c, *decision)
                     for c in self._costs.workers)

    # ------------------------------------------------------------------
    # one worker attempt: segmented pull → grads → segmented push
    # ------------------------------------------------------------------

    def _pull_layers(self, worker: int) -> Tuple[int, List[Any]]:
        """Pull every forward segment at one pinned version."""
        while True:
            version: Optional[int] = None
            buffers: Dict[int, Any] = {}
            try:
                for bucket in self.plan.forward:
                    v, flats = self.server.pull_bucket(
                        bucket, version=version, worker=worker)
                    version = v
                    buffers.update(flats)
            except StaleVersion:
                continue          # snapshot evicted mid-pull: restart at head
            layers = [unflatten_tree(buffers[l], self.specs[l])
                      for l in range(len(self.specs))]
            return version, layers

    def _compute(self, worker: int, batch) -> Tuple[float, int, List[Any]]:
        """Pull (pinning a version) and compute gradients against it."""
        version, layers = self._pull_layers(worker)
        loss, grads = self._grad_fn(layers, batch)
        return float(loss), version, grads

    def _push(self, worker: int, version: int,
              grads: List[Any]) -> PushResult:
        """Push every backward segment; the last one commits."""
        result: Optional[PushResult] = None
        for bucket in self.plan.backward:
            flat_grads = {l: flatten_tree(grads[l], self.specs[l])
                          for l in bucket}
            result = self.server.push_bucket(worker, version, bucket,
                                             flat_grads)
        assert result is not None, "plan.backward committed no push"
        return result

    # ------------------------------------------------------------------
    # the event loop
    # ------------------------------------------------------------------

    def run(self, num_pushes: int,
            batch_fn: Callable[[int, int], Any]) -> AsyncRunLog:
        """Run until ``num_pushes`` gradient pushes were *accepted*.

        Each worker pulls + computes at the *start* of its iteration and
        commits its push one per-worker iteration duration later — other
        workers' commits land in between, which is where staleness comes
        from.  ``batch_fn(worker, attempt_idx) -> batch`` supplies data;
        the attempt index increments per computation (including retries
        after a stale rejection), so every retry sees fresh data."""
        if num_pushes < 1:
            raise ValueError(f"num_pushes must be >= 1, got {num_pushes}")
        log = AsyncRunLog()
        W = self.topology.num_workers
        attempts = [0] * W
        retries = [0] * W
        num_accepted = 0
        # (commit time, worker id, compute version, loss, grads); one
        # in-flight iteration per worker makes (time, id) unique, so the
        # payload is never compared.
        queue: List[Tuple[float, int, int, float, List[Any]]] = []
        for w in range(W):
            loss, version, grads = self._compute(w, batch_fn(w, 0))
            attempts[w] = 1
            heapq.heappush(queue, (self._durations[w], w, version, loss,
                                   grads))
        while num_accepted < num_pushes:
            t, w, version, loss, grads = heapq.heappop(queue)
            result = self._push(w, version, grads)
            log.events.append(AsyncPushEvent(
                worker=w, sim_time=t, version=version, result=result,
                loss=loss, retries=retries[w]))
            num_accepted += int(result.accepted)
            retries[w] = retries[w] + 1 if not result.accepted else 0
            loss, version, grads = self._compute(w, batch_fn(w, attempts[w]))
            attempts[w] += 1
            heapq.heappush(queue, (t + self._durations[w], w, version, loss,
                                   grads))
        return log

    # ------------------------------------------------------------------
    # interop
    # ------------------------------------------------------------------

    def layer_params(self) -> List[Any]:
        """Head-version parameters, unflattened to the layer pytrees."""
        return [unflatten_tree(f, s)
                for f, s in zip(self.server.flats(), self.specs)]
